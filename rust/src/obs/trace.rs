//! Chrome trace-event exporter and a minimal well-formedness checker.
//!
//! [`chrome_trace_json`] serializes the current event ring as a Chrome
//! trace-event JSON document — open it in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`. Spans become
//! `"ph": "X"` complete events, markers become `"ph": "i"` instants,
//! and each recorded thread gets a `thread_name` metadata event so the
//! tracks read `executor-0`, `stream-miner`, `main`... The category
//! (`cat`) is the event-name prefix before the first `.`, so the UI can
//! filter by layer (`engine`, `fim`, `stream`).
//!
//! [`validate_trace`] is a tiny recursive-descent JSON parser used by
//! tests and CI smoke runs to prove the emitted trace parses and has
//! the required keys — no serde needed.

use crate::util::json::json_str;

use super::span::{events, thread_names, EventKind};

/// Serialize the current event ring as a Chrome trace-event JSON
/// document (`{"traceEvents": [...]}` object form).
pub fn chrome_trace_json() -> String {
    let (evs, dropped) = events();
    let mut out = String::from("{\"traceEvents\": [\n");
    let mut first = true;
    let mut push = |s: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        out.push_str(&s);
        *first = false;
    };
    for (tid, name) in thread_names() {
        push(
            format!(
                "  {{\"ph\": \"M\", \"pid\": 1, \"tid\": {tid}, \"name\": \"thread_name\", \
                 \"args\": {{\"name\": {}}}}}",
                json_str(&name)
            ),
            &mut first,
        );
    }
    for e in &evs {
        let cat = e.name.split('.').next().unwrap_or("obs");
        let mut args = String::from("{");
        for (i, (k, v)) in e.args.iter().enumerate() {
            if i > 0 {
                args.push_str(", ");
            }
            args.push_str(&format!("{}: {v}", json_str(k)));
        }
        args.push('}');
        let row = match e.kind {
            EventKind::Span => format!(
                "  {{\"ph\": \"X\", \"pid\": 1, \"tid\": {}, \"ts\": {}, \"dur\": {}, \
                 \"name\": {}, \"cat\": {}, \"args\": {args}}}",
                e.tid,
                e.start_us,
                e.dur_us,
                json_str(e.name),
                json_str(cat)
            ),
            EventKind::Instant => format!(
                "  {{\"ph\": \"i\", \"pid\": 1, \"tid\": {}, \"ts\": {}, \"s\": \"t\", \
                 \"name\": {}, \"cat\": {}, \"args\": {args}}}",
                e.tid,
                e.start_us,
                json_str(e.name),
                json_str(cat)
            ),
        };
        push(row, &mut first);
    }
    out.push_str("\n], \"otherData\": {\"dropped_events\": ");
    out.push_str(&dropped.to_string());
    out.push_str("}}\n");
    out
}

/// Write [`chrome_trace_json`] to `path` (parent directories created).
pub fn write_chrome_trace(path: &str) -> crate::error::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, chrome_trace_json())?;
    Ok(())
}

// ---------------------------------------------------------------------
// Minimal JSON checker.
// ---------------------------------------------------------------------

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Parser<'a> {
        Parser { s: s.as_bytes(), i: 0 }
    }

    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        match self.peek() {
            Some(b'{') => self.object().map(|_| ()),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(|_| ()),
            Some(b't') => self.literal("true"),
            Some(b'f') => self.literal("false"),
            Some(b'n') => self.literal("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        self.skip_ws();
        if self.s[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<(), String> {
        self.skip_ws();
        let start = self.i;
        if self.s.get(self.i) == Some(&b'-') {
            self.i += 1;
        }
        while self
            .s
            .get(self.i)
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        if self.i == start {
            Err(self.err("expected a number"))
        } else {
            Ok(())
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while let Some(&c) = self.s.get(self.i) {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = *self.s.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match esc {
                        b'u' => {
                            if self.i + 4 > self.s.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            self.i += 4;
                            out.push('?');
                        }
                        _ => out.push(esc as char),
                    }
                }
                c => out.push(c as char),
            }
        }
        Err(self.err("unterminated string"))
    }

    /// Parse an object, returning its top-level key names.
    fn object(&mut self) -> Result<Vec<String>, String> {
        self.expect(b'{')?;
        let mut keys = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(keys);
        }
        loop {
            keys.push(self.string()?);
            self.expect(b':')?;
            self.value()?;
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(keys);
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.expect(b'[')?;
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.value()?;
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

/// Check that `text` is a well-formed Chrome trace: valid JSON, a
/// top-level `traceEvents` array, and every event object carrying `ph`
/// and `name` keys (plus `ts` for non-metadata events). Returns the
/// number of events.
pub fn validate_trace(text: &str) -> Result<usize, String> {
    let mut p = Parser::new(text);
    p.expect(b'{')?;
    let mut seen_trace_events = false;
    let mut n_events = 0usize;
    loop {
        let key = p.string()?;
        p.expect(b':')?;
        if key == "traceEvents" {
            seen_trace_events = true;
            p.expect(b'[')?;
            if p.peek() == Some(b']') {
                p.i += 1;
            } else {
                loop {
                    let keys = p.object()?;
                    for required in ["ph", "name"] {
                        if !keys.iter().any(|k| k == required) {
                            return Err(format!("event {n_events} missing key '{required}'"));
                        }
                    }
                    n_events += 1;
                    match p.peek() {
                        Some(b',') => p.i += 1,
                        Some(b']') => {
                            p.i += 1;
                            break;
                        }
                        _ => return Err(p.err("expected ',' or ']'")),
                    }
                }
            }
        } else {
            p.value()?;
        }
        match p.peek() {
            Some(b',') => p.i += 1,
            Some(b'}') => {
                p.i += 1;
                break;
            }
            _ => return Err(p.err("expected ',' or '}'")),
        }
    }
    p.skip_ws();
    if p.i != p.s.len() {
        return Err(p.err("trailing content"));
    }
    if !seen_trace_events {
        return Err("no traceEvents array".to_string());
    }
    Ok(n_events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn exported_trace_is_well_formed() {
        obs::set_enabled(true);
        {
            let mut g = obs::span("trace.test.outer");
            g.arg("items", 3);
            let _inner = obs::span("trace.test.inner");
        }
        obs::instant("trace.test.marker");
        let json = chrome_trace_json();
        let n = validate_trace(&json).expect("trace parses");
        assert!(n >= 3, "metadata + at least two spans: {n}\n{json}");
        assert!(json.contains("\"trace.test.outer\""), "{json}");
        assert!(json.contains("\"ph\": \"X\""), "{json}");
        assert!(json.contains("\"ph\": \"i\""), "{json}");
        assert!(json.contains("\"thread_name\""), "{json}");
        assert!(json.contains("\"items\": 3"), "{json}");
        assert!(json.contains("\"dropped_events\""), "{json}");
    }

    #[test]
    fn checker_accepts_minimal_and_rejects_malformed() {
        assert_eq!(validate_trace("{\"traceEvents\": []}"), Ok(0));
        let ok = "{\"traceEvents\": [{\"ph\": \"X\", \"name\": \"a\", \"ts\": 1, \"dur\": 2}]}";
        assert_eq!(validate_trace(ok), Ok(1));
        assert!(validate_trace("{\"traceEvents\": [{\"ph\": \"X\"}]}").is_err(), "missing name");
        assert!(validate_trace("{\"traceEvents\": [}").is_err());
        assert!(validate_trace("{\"other\": 1}").is_err(), "no traceEvents");
        assert!(validate_trace("{\"traceEvents\": []} trailing").is_err());
        // Escapes and nesting survive the minimal parser.
        let nested = "{\"traceEvents\": [{\"ph\": \"M\", \"name\": \"t\\\"n\", \
                      \"args\": {\"name\": \"executor-0\", \"xs\": [1, -2.5e3, null]}}]}";
        assert_eq!(validate_trace(nested), Ok(1));
    }

    #[test]
    fn write_chrome_trace_creates_parent_dirs() {
        let dir = std::env::temp_dir().join("rdd_eclat_obs_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("sub").join("out.trace.json");
        write_chrome_trace(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        validate_trace(&text).expect("written trace parses");
    }
}
