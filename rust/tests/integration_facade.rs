//! Façade integration: the `Variant` registry, the `MiningSession`
//! builder, and sink parity — every public mining path must agree with
//! the pre-redesign oracles regardless of how emissions are routed.

use rdd_eclat::algorithms::{EclatV1, EclatV2, EclatV3, EclatV4, EclatV5};
use rdd_eclat::data::clickstream::{self, ClickParams};
use rdd_eclat::data::quest::{self, QuestParams};
use rdd_eclat::fim::bottomup::reference;
use rdd_eclat::fim::{construct_classes, MineScratch, Tidset, VerticalDb};
use rdd_eclat::prelude::*;

fn ctx() -> ClusterContext {
    ClusterContext::builder().cores(2).build()
}

fn small_dbs() -> Vec<(&'static str, Database)> {
    let click = ClickParams {
        sessions: 200,
        items: 50,
        avg_len: 5.0,
        skew: 1.1,
        locality: 0.5,
        radius: 6,
        drift: 0.0,
    };
    vec![
        ("quest_dense", quest::generate(&QuestParams::tid(10.0, 4.0, 150, 20), 13)),
        ("quest_sparse", quest::generate(&QuestParams::tid(6.0, 3.0, 250, 50), 29)),
        ("clickstream", clickstream::generate(&click, 7)),
    ]
}

/// Strength order of `TopKSink` (support desc, shorter first, then lex)
/// — duplicated here as the independent oracle.
fn sort_by_strength(v: &mut [Frequent]) {
    v.sort_by(|a, b| {
        b.support
            .cmp(&a.support)
            .then_with(|| a.items.len().cmp(&b.items.len()))
            .then_with(|| a.items.cmp(&b.items))
    });
}

#[test]
fn every_variant_runs_through_the_facade_and_all_agree() {
    // All ten registry entries are exact miners, so their itemset sets
    // must be identical — exercised through MiningSession, not concrete
    // types.
    let db = Database::from_rows(vec![
        vec![1, 3, 4],
        vec![2, 3, 5],
        vec![1, 2, 3, 5],
        vec![2, 5],
        vec![1, 3, 5],
        vec![2, 3, 5],
    ]);
    let ctx = ctx();
    let session = MiningSession::on(&ctx).db(&db).min_sup(MinSup::count(2));
    let mut oracle: Option<Vec<Frequent>> = None;
    for &v in Variant::all() {
        let result = session.run(v).unwrap_or_else(|e| panic!("{v}: {e}"));
        assert_eq!(result.algorithm, v.name());
        let mut got = result.frequents;
        sort_frequents(&mut got);
        match &oracle {
            None => oracle = Some(got),
            Some(want) => assert_eq!(&got, want, "{v}"),
        }
    }
}

#[test]
fn session_matches_direct_construction_for_all_rdd_variants() {
    // Bypassing the façade (concrete types, explicit options) must give
    // byte-identical results and the same partition-load capture.
    let db = quest::generate(&QuestParams::tid(8.0, 4.0, 120, 18), 3);
    let ctx = ctx();
    let opts = EclatOptions { tri_matrix: true, partitions: 4, ..Default::default() };
    let session =
        MiningSession::on(&ctx).db(&db).min_sup(MinSup::fraction(0.05)).options(opts.clone());
    let direct: Vec<Box<dyn Algorithm>> = vec![
        Box::new(EclatV1::with_options(opts.clone())),
        Box::new(EclatV2::with_options(opts.clone())),
        Box::new(EclatV3::with_options(opts.clone())),
        Box::new(EclatV4::with_options(opts.clone())),
        Box::new(EclatV5::with_options(opts)),
    ];
    for (v, algo) in Variant::RDD_ECLAT.iter().zip(&direct) {
        let via_session = session.run(*v).unwrap();
        let via_direct = algo.run_on(&ctx, &db, MinSup::fraction(0.05)).unwrap();
        let (mut a, mut b) = (via_session.frequents, via_direct.frequents);
        sort_frequents(&mut a);
        sort_frequents(&mut b);
        assert_eq!(a, b, "{v}");
        assert_eq!(
            via_session.partition_loads.len(),
            via_direct.partition_loads.len(),
            "{v} load capture"
        );
    }
}

#[test]
fn sink_parity_across_classes_seeds_and_thresholds() {
    // CollectSink == decoded PooledSink == the pre-refactor reference,
    // per class, across datasets and a min_sup sweep; shared scratches
    // and a shared pool give recycled buffers every chance to leak.
    let mut scratch = MineScratch::<Tidset>::new();
    let mut pool = PooledSink::new();
    for (tag, db) in &small_dbs() {
        for min_sup in [2u32, 3, 5, 8] {
            let vdb = VerticalDb::build(db, min_sup);
            for class in construct_classes(&vdb, min_sup, None) {
                let mut want = Vec::new();
                reference::bottom_up::<Tidset>(&[class.prefix], &class.members, min_sup, &mut want);
                sort_frequents(&mut want);

                let mut collected: Vec<Frequent> = Vec::new();
                class.mine_into(&mut scratch, min_sup, &mut collected);
                sort_frequents(&mut collected);
                assert_eq!(collected, want, "{tag} collect prefix={} sup={min_sup}", class.prefix);

                pool.clear();
                class.mine_into(&mut scratch, min_sup, &mut pool);
                let mut decoded = pool.decode();
                sort_frequents(&mut decoded);
                assert_eq!(decoded, want, "{tag} pooled prefix={} sup={min_sup}", class.prefix);

                let mut count = CountSink::new();
                class.mine_into(&mut scratch, min_sup, &mut count);
                assert_eq!(count.count as usize, want.len(), "{tag} count sink");
            }
        }
    }
}

#[test]
fn whole_db_pooled_mining_matches_collect_mining() {
    for (tag, db) in &small_dbs() {
        for min_sup in [2u32, 5, 9] {
            let mut want = SeqEclat::mine(db, MinSup::count(min_sup));
            sort_frequents(&mut want);
            let mut pool = PooledSink::new();
            SeqEclat::mine_into(db, MinSup::count(min_sup), &mut pool);
            let mut got = pool.decode();
            sort_frequents(&mut got);
            assert_eq!(got, want, "{tag} sup={min_sup}");

            // Diffset path through a pool as well.
            let mut want_d = SeqEclatDiffset::mine(db, MinSup::count(min_sup));
            sort_frequents(&mut want_d);
            assert_eq!(want_d, want, "{tag} diffset parity sup={min_sup}");
        }
    }
}

#[test]
fn topk_sink_matches_sort_then_truncate_oracle() {
    for (tag, db) in &small_dbs() {
        for min_sup in [3u32, 6] {
            let mut all = SeqEclat::mine(db, MinSup::count(min_sup));
            sort_by_strength(&mut all);
            for k in [0usize, 1, 7, 50, 10_000] {
                let mut sink = TopKSink::new(k);
                SeqEclat::mine_into(db, MinSup::count(min_sup), &mut sink);
                let got = sink.into_sorted();
                let mut want = all.clone();
                want.truncate(k);
                assert_eq!(got, want, "{tag} sup={min_sup} k={k}");
            }
        }
    }
}

#[test]
fn facade_fimresult_contains_accepts_permuted_queries() {
    let db = Database::from_rows(vec![vec![1, 2, 3], vec![1, 2, 3], vec![2, 3]]);
    let ctx = ctx();
    let r = MiningSession::on(&ctx).db(&db).min_sup(MinSup::count(2)).run(Variant::V5).unwrap();
    assert!(r.contains(&[1, 2, 3], 2));
    assert!(r.contains(&[3, 2, 1], 2), "permuted query must match");
    assert!(r.contains(&[3, 2], 3));
}

#[test]
fn list_registry_is_complete_and_parseable() {
    assert_eq!(Variant::all().len(), 10);
    for &v in Variant::all() {
        let parsed: Variant = v.name().parse().unwrap();
        assert_eq!(parsed, v);
        assert!(!v.describe().is_empty());
    }
    let err = "bogus".parse::<Variant>().unwrap_err().to_string();
    assert!(err.contains("eclatV1") && err.contains("seq-fpgrowth"), "{err}");
}
