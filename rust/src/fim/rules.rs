//! Association-rule generation — step 2 of ARM (§2.1 of the paper):
//! from the frequent itemsets, produce every confident rule `X ⇒ Y`
//! with `X ∪ Y` frequent, `X ∩ Y = ∅`, and confidence
//! `σ(X∪Y)/σ(X) ≥ min_conf`.

use std::collections::HashMap;

use crate::util::json::json_f64;

use super::itemset::{Frequent, Item, ItemSet};

/// An association rule `antecedent ⇒ consequent`.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    /// Left-hand side (non-empty, sorted).
    pub antecedent: ItemSet,
    /// Right-hand side (non-empty, sorted, disjoint from lhs).
    pub consequent: ItemSet,
    /// Support count of `antecedent ∪ consequent`.
    pub support: u32,
    /// `σ(X∪Y) / σ(X)`.
    pub confidence: f64,
    /// `confidence / (σ(Y)/n)` — lift, when the db size is known.
    pub lift: Option<f64>,
}

impl std::fmt::Display for Rule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let fmt_set = |s: &[Item]| {
            s.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" ")
        };
        write!(
            f,
            "{} => {}  (sup={}, conf={:.3})",
            fmt_set(&self.antecedent),
            fmt_set(&self.consequent),
            self.support,
            self.confidence
        )
    }
}

/// Generate all confident rules from a mined frequent-itemset collection.
/// `db_size` (when known) enables lift. Standard subset enumeration: for
/// each frequent itemset of length ≥ 2, every non-empty proper subset is a
/// candidate antecedent.
pub fn generate_rules(
    frequents: &[Frequent],
    min_conf: f64,
    db_size: Option<usize>,
) -> Vec<Rule> {
    let support_map: HashMap<&[Item], u32> =
        frequents.iter().map(|f| (f.items.as_slice(), f.support)).collect();
    let mut rules = Vec::new();
    for f in frequents {
        let k = f.items.len();
        if k < 2 {
            continue;
        }
        // Enumerate non-empty proper subsets via bitmask (itemsets in FIM
        // practice are short; guard anyway).
        if k > 20 {
            continue;
        }
        for mask in 1..((1u32 << k) - 1) {
            let mut ante = Vec::new();
            let mut cons = Vec::new();
            for (idx, &item) in f.items.iter().enumerate() {
                if (mask >> idx) & 1 == 1 {
                    ante.push(item);
                } else {
                    cons.push(item);
                }
            }
            let Some(&ante_sup) = support_map.get(ante.as_slice()) else {
                continue; // can't happen for a correct miner, but stay safe
            };
            let confidence = f.support as f64 / ante_sup as f64;
            if confidence >= min_conf {
                let lift = match (db_size, support_map.get(cons.as_slice())) {
                    (Some(n), Some(&cons_sup)) if cons_sup > 0 => {
                        Some(confidence / (cons_sup as f64 / n as f64))
                    }
                    _ => None,
                };
                rules.push(Rule { antecedent: ante, consequent: cons, support: f.support, confidence, lift });
            }
        }
    }
    rules.sort_by(|a, b| {
        b.confidence
            .partial_cmp(&a.confidence)
            .unwrap()
            .then_with(|| b.support.cmp(&a.support))
            .then_with(|| a.antecedent.cmp(&b.antecedent))
    });
    rules
}

/// Serialize rules as a JSON array (items are integers, so no string
/// escaping is needed beyond the fixed keys). Consumed by the CLI's
/// `--json` outputs and the streaming snapshot writer.
pub fn rules_to_json(rules: &[Rule]) -> String {
    let fmt_set = |s: &[Item]| {
        let inner: Vec<String> = s.iter().map(|i| i.to_string()).collect();
        format!("[{}]", inner.join(", "))
    };
    let mut out = String::from("[\n");
    for (i, r) in rules.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"antecedent\": {}, \"consequent\": {}, \"support\": {}, \"confidence\": {}, \"lift\": {}}}{}\n",
            fmt_set(&r.antecedent),
            fmt_set(&r.consequent),
            r.support,
            json_f64(r.confidence),
            r.lift.map_or("null".to_string(), json_f64),
            if i + 1 < rules.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::apriori::apriori;
    use crate::fim::transaction::Database;

    fn mined() -> (Database, Vec<Frequent>) {
        let db = Database::from_rows(vec![
            vec![1, 2],
            vec![1, 2],
            vec![1, 2, 3],
            vec![1, 3],
        ]);
        let f = apriori(&db, 2);
        (db, f)
    }

    #[test]
    fn rules_have_correct_confidence() {
        let (db, f) = mined();
        let rules = generate_rules(&f, 0.0, Some(db.len()));
        // σ(12)=3, σ(1)=4 -> conf(1=>2)=0.75 ; σ(2)=3 -> conf(2=>1)=1.0
        let r12 = rules
            .iter()
            .find(|r| r.antecedent == vec![1] && r.consequent == vec![2])
            .unwrap();
        assert!((r12.confidence - 0.75).abs() < 1e-12);
        let r21 = rules
            .iter()
            .find(|r| r.antecedent == vec![2] && r.consequent == vec![1])
            .unwrap();
        assert!((r21.confidence - 1.0).abs() < 1e-12);
        // lift(2=>1) = 1.0 / (4/4) = 1.0
        assert!((r21.lift.unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_conf_filters() {
        let (db, f) = mined();
        let all = generate_rules(&f, 0.0, Some(db.len()));
        let high = generate_rules(&f, 0.9, Some(db.len()));
        assert!(high.len() < all.len());
        assert!(high.iter().all(|r| r.confidence >= 0.9));
    }

    #[test]
    fn rules_sorted_by_confidence() {
        let (db, f) = mined();
        let rules = generate_rules(&f, 0.0, Some(db.len()));
        for w in rules.windows(2) {
            assert!(w[0].confidence >= w[1].confidence);
        }
    }

    #[test]
    fn antecedent_consequent_disjoint_and_nonempty() {
        let (db, f) = mined();
        for r in generate_rules(&f, 0.0, Some(db.len())) {
            assert!(!r.antecedent.is_empty() && !r.consequent.is_empty());
            for a in &r.antecedent {
                assert!(!r.consequent.contains(a));
            }
        }
    }

    #[test]
    fn no_rules_from_singletons() {
        let f = vec![Frequent::new(vec![1], 5)];
        assert!(generate_rules(&f, 0.0, None).is_empty());
    }

    #[test]
    fn json_shape() {
        let (db, f) = mined();
        let rules = generate_rules(&f, 0.9, Some(db.len()));
        let json = rules_to_json(&rules);
        assert!(json.starts_with("[\n") && json.trim_end().ends_with(']'));
        assert!(json.contains("\"antecedent\": [2]"), "{json}");
        assert!(json.contains("\"confidence\": 1.000000"), "{json}");
        // One comma fewer than there are rules, none trailing.
        assert_eq!(json.matches("},\n").count(), rules.len() - 1, "{json}");
        assert!(!json.contains(",\n]"), "{json}");
        // Rules without db_size carry lift: null.
        let no_lift = generate_rules(&f, 0.9, None);
        assert!(rules_to_json(&no_lift).contains("\"lift\": null"));
        assert_eq!(rules_to_json(&[]), "[\n]\n");
    }
}
