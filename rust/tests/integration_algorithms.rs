//! Cross-algorithm correctness: every RDD variant and every sequential
//! miner must produce the identical frequent-itemset set (with identical
//! supports) on randomized databases — the core property of the
//! reproduction (DESIGN.md §7).

use rdd_eclat::algorithms::{
    Algorithm, EclatOptions, EclatV1, EclatV2, EclatV3, EclatV4, EclatV5, RddApriori,
    SeqApriori, SeqEclat, SeqEclatDiffset, SeqFpGrowth,
};
use rdd_eclat::data::Database;
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{sort_frequents, Frequent, MinSup};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, prop_assert_eq, Config};

fn random_db(rng: &mut Rng) -> Database {
    let n_items = rng.range(3, 25) as u32;
    let n_txns = rng.range(5, 120);
    let density = 0.15 + rng.f64() * 0.4;
    let rows: Vec<Vec<u32>> = (0..n_txns)
        .map(|_| (0..n_items).filter(|_| rng.chance(density)).collect())
        .filter(|t: &Vec<u32>| !t.is_empty())
        .collect();
    Database::from_rows(rows)
}

fn mined(algo: &dyn Algorithm, ctx: &ClusterContext, db: &Database, ms: MinSup) -> Vec<Frequent> {
    let mut v = algo.run_on(ctx, db, ms).expect("run").frequents;
    sort_frequents(&mut v);
    v
}

#[test]
fn all_algorithms_agree_on_random_databases() {
    let ctx = ClusterContext::builder().cores(2).build();
    let algos: Vec<Box<dyn Algorithm>> = vec![
        Box::new(EclatV1::default()),
        Box::new(EclatV2::default()),
        Box::new(EclatV3::default()),
        Box::new(EclatV4::default()),
        Box::new(EclatV5::default()),
        Box::new(RddApriori),
        Box::new(SeqEclat),
        Box::new(SeqEclatDiffset),
        Box::new(SeqApriori),
        Box::new(SeqFpGrowth),
    ];
    check(Config::default().cases(25).seed(0xA11), |rng| {
        let db = random_db(rng);
        // `2 +` keeps the range non-empty even when filtering left the
        // database with fewer than three transactions.
        let min_sup = MinSup::count(rng.range(1, 2 + db.len() / 3) as u32);
        let want = mined(&SeqApriori, &ctx, &db, min_sup);
        for algo in &algos {
            let got = mined(algo.as_ref(), &ctx, &db, min_sup);
            prop_assert_eq(got.len(), want.len(), algo.name())?;
            prop_assert_eq(got == want, true, algo.name())?;
        }
        Ok(())
    });
}

#[test]
fn tri_matrix_and_partition_count_do_not_change_results() {
    let ctx = ClusterContext::builder().cores(2).build();
    check(Config::default().cases(15).seed(0xB22), |rng| {
        let db = random_db(rng);
        let min_sup = MinSup::count(rng.range(1, 6) as u32);
        let base = mined(&EclatV4::default(), &ctx, &db, min_sup);
        for tri in [true, false] {
            for p in [1usize, 3, 17] {
                let algo = EclatV4::with_options(EclatOptions {
                    tri_matrix: tri,
                    partitions: p,
                    ..Default::default()
                });
                let got = mined(&algo, &ctx, &db, min_sup);
                prop_assert_eq(got == base, true, &format!("tri={tri} p={p}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn fraction_thresholds_match_counts() {
    let ctx = ClusterContext::builder().cores(2).build();
    let mut rng = Rng::new(0xC33);
    for _ in 0..5 {
        let db = random_db(&mut rng);
        let n = db.len();
        if n < 2 {
            continue; // fraction thresholds need a non-trivial db
        }
        let count = rng.range(1, 1 + n / 2).max(1) as u32;
        let frac = count as f64 / n as f64;
        let a = mined(&EclatV5::default(), &ctx, &db, MinSup::count(count));
        let b = mined(&EclatV5::default(), &ctx, &db, MinSup::fraction(frac));
        assert_eq!(a, b, "count {count} vs fraction {frac} on n={n}");
    }
}

#[test]
fn supports_match_bruteforce_subset_counting() {
    let ctx = ClusterContext::builder().cores(2).build();
    check(Config::default().cases(10).seed(0xD44), |rng| {
        let db = random_db(rng);
        let min_sup = MinSup::count(rng.range(1, 5) as u32);
        let got = mined(&EclatV3::default(), &ctx, &db, min_sup);
        for f in got.iter().take(50) {
            let brute = rdd_eclat::fim::apriori::support_of(&db, &f.items);
            prop_assert_eq(f.support, brute, &format!("{:?}", f.items))?;
        }
        Ok(())
    });
}

#[test]
fn completeness_no_frequent_itemset_missed() {
    // Exhaustive check on small universes: enumerate ALL itemsets up to
    // size 3 and verify membership matches the threshold exactly.
    let ctx = ClusterContext::builder().cores(2).build();
    check(Config::default().cases(10).seed(0xE55), |rng| {
        let n_items = rng.range(3, 8) as u32;
        let rows: Vec<Vec<u32>> = (0..rng.range(5, 30))
            .map(|_| (0..n_items).filter(|_| rng.chance(0.5)).collect())
            .filter(|t: &Vec<u32>| !t.is_empty())
            .collect();
        let db = Database::from_rows(rows);
        let min_sup = rng.range(1, 4) as u32;
        let got = mined(&EclatV1::default(), &ctx, &db, MinSup::count(min_sup));
        let got_set: std::collections::HashSet<Vec<u32>> =
            got.iter().map(|f| f.items.clone()).collect();
        // All 1-, 2-, 3-itemsets.
        let items: Vec<u32> = (0..n_items).collect();
        for i in 0..items.len() {
            for subset in [vec![items[i]]] {
                let sup = rdd_eclat::fim::apriori::support_of(&db, &subset);
                prop_assert_eq(got_set.contains(&subset), sup >= min_sup, &format!("{subset:?}"))?;
            }
            for j in (i + 1)..items.len() {
                let pair = vec![items[i], items[j]];
                let sup = rdd_eclat::fim::apriori::support_of(&db, &pair);
                prop_assert_eq(got_set.contains(&pair), sup >= min_sup, &format!("{pair:?}"))?;
                for k in (j + 1)..items.len() {
                    let triple = vec![items[i], items[j], items[k]];
                    let sup = rdd_eclat::fim::apriori::support_of(&db, &triple);
                    prop_assert_eq(
                        got_set.contains(&triple),
                        sup >= min_sup,
                        &format!("{triple:?}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn cores_do_not_change_results() {
    let mut rng = Rng::new(0xF66);
    let db = random_db(&mut rng);
    let min_sup = MinSup::count(2);
    let mut reference: Option<Vec<Frequent>> = None;
    for cores in [1usize, 2, 4, 8] {
        let ctx = ClusterContext::builder().cores(cores).build();
        let got = mined(&EclatV4::default(), &ctx, &db, min_sup);
        match &reference {
            None => reference = Some(got),
            Some(want) => assert_eq!(&got, want, "cores={cores}"),
        }
    }
}

#[test]
fn empty_and_degenerate_databases() {
    let ctx = ClusterContext::builder().cores(2).build();
    let algos: Vec<Box<dyn Algorithm>> = vec![
        Box::new(EclatV1::default()),
        Box::new(EclatV2::default()),
        Box::new(EclatV3::default()),
        Box::new(EclatV4::default()),
        Box::new(EclatV5::default()),
        Box::new(RddApriori),
    ];
    // Single transaction, single item; and all-identical transactions.
    for db in [
        Database::from_rows(vec![vec![7]]),
        Database::from_rows(vec![vec![1, 2]; 10]),
    ] {
        for algo in &algos {
            let r = algo.run_on(&ctx, &db, MinSup::count(1)).unwrap();
            assert!(!r.is_empty(), "{} on degenerate db", algo.name());
        }
    }
    // Nothing frequent.
    let db = Database::from_rows(vec![vec![1], vec![2], vec![3]]);
    for algo in &algos {
        let r = algo.run_on(&ctx, &db, MinSup::count(2)).unwrap();
        assert!(r.is_empty(), "{}", algo.name());
    }
}

/// Shared degenerate-input hardening: an empty database, `min_sup`
/// larger than `|DB|`, and vertical lists with zero or one frequent item
/// must not panic in any of the five variants (these shapes reach
/// `DefaultClassPartitioner::for_items(0|1)` and
/// `mine_equivalence_classes` with an empty/singleton vertical list).
#[test]
fn degenerate_inputs_never_panic_across_all_variants() {
    let ctx = ClusterContext::builder().cores(2).build();
    let variants: Vec<Box<dyn Algorithm>> = vec![
        Box::new(EclatV1::default()),
        Box::new(EclatV2::default()),
        Box::new(EclatV3::default()),
        Box::new(EclatV4::default()),
        Box::new(EclatV5::default()),
    ];
    let cases: Vec<(&str, Database, u32)> = vec![
        ("empty db", Database::from_rows(vec![]), 1),
        ("empty db, high min_sup", Database::from_rows(vec![]), 50),
        (
            "min_sup > |DB|",
            Database::from_rows(vec![vec![1, 2], vec![1, 2], vec![2, 3]]),
            4,
        ),
        (
            "exactly one frequent item",
            Database::from_rows(vec![vec![1, 2], vec![1, 3], vec![1, 4]]),
            3,
        ),
        (
            "one frequent item, others on the edge",
            Database::from_rows(vec![vec![5], vec![5], vec![6]]),
            2,
        ),
        ("single txn, single item", Database::from_rows(vec![vec![9]]), 1),
    ];
    for (label, db, min_sup) in &cases {
        for algo in &variants {
            let r = algo
                .run_on(&ctx, db, MinSup::count(*min_sup))
                .unwrap_or_else(|e| panic!("{} on {label}: {e}", algo.name()));
            // Cross-check against the sequential oracle.
            let mut want = rdd_eclat::fim::apriori::apriori(db, *min_sup);
            let mut got = r.frequents;
            sort_frequents(&mut want);
            sort_frequents(&mut got);
            assert_eq!(got, want, "{} on {label}", algo.name());
        }
    }
    // The degenerate shapes also hit the partitioner/miner entry points
    // directly: zero and one frequent items must stay in-range.
    use rdd_eclat::algorithms::partitioners::DefaultClassPartitioner;
    use rdd_eclat::engine::Partitioner;
    for n in [0usize, 1, 2] {
        let p = DefaultClassPartitioner::for_items(n);
        assert!(p.num_partitions() >= 1, "for_items({n})");
        assert!(p.partition(&0) < p.num_partitions(), "for_items({n})");
    }
}

/// The distributed Phase-1 property (tentpole regression): EclatV1 over
/// 1, 2, 4 and 7 partitions yields byte-identical sorted frequents to
/// the sequential oracle on QUEST-generated data, across min_sup sweeps.
#[test]
fn eclat_v1_partition_counts_match_seq_eclat_on_quest_data() {
    use rdd_eclat::data::quest::{generate, QuestParams};

    let mut seeds = Rng::new(0x5EED_F1);
    for case in 0..3 {
        let seed = seeds.next_u64();
        let db = generate(&QuestParams::tid(8.0, 3.0, 400, 60), seed);
        for min_sup in [2u32, 8, 40] {
            let mut want = SeqEclat::mine(&db, MinSup::count(min_sup));
            sort_frequents(&mut want);
            for parts in [1usize, 2, 4, 7] {
                let ctx = ClusterContext::builder()
                    .cores(2)
                    .default_parallelism(parts)
                    .build();
                let mut got = EclatV1::default()
                    .run_on(&ctx, &db, MinSup::count(min_sup))
                    .unwrap()
                    .frequents;
                sort_frequents(&mut got);
                assert_eq!(
                    got, want,
                    "case {case} seed {seed:#x} min_sup {min_sup} parts {parts}"
                );
            }
        }
    }
}
