//! Live rule mining over a drifting clickstream — the streaming
//! subsystem end to end: micro-batch source → sliding window →
//! incremental vertical store → per-batch frequent-itemset and
//! association-rule snapshots.
//!
//! The catalogue's popular region rotates over time
//! (`ClickParams::drift()`), so windows genuinely churn: items rise into
//! and fall out of the frequent set as the hot spot moves past them. The
//! demo prints each emission's plan (full re-mine vs delta) and compares
//! total wall time against re-mining every window from scratch.
//!
//! ```text
//! cargo run --release --example streaming_clickstream
//! ```

use std::time::Duration;

use rdd_eclat::data::clickstream::ClickParams;
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::MinSup;
use rdd_eclat::stream::{
    BatchSource, ClickstreamSource, MineMode, StreamConfig, StreamingMiner, WindowSpec,
};
use rdd_eclat::util::time::fmt_duration;

const BATCH: usize = 250;
const WINDOW: usize = 16;
const SLIDE: usize = 1;
const BATCHES: usize = 40;

fn drive(mode: MineMode, quiet: bool) -> rdd_eclat::error::Result<(Duration, usize, usize)> {
    let params = ClickParams { sessions: BATCHES * BATCH, ..ClickParams::drift() };
    let mut source = ClickstreamSource::new(params, 7, BATCH);
    let ctx = ClusterContext::builder().build();
    let cfg = StreamConfig::new(WindowSpec::sliding(WINDOW, SLIDE), MinSup::fraction(0.008))
        .mode(mode)
        .min_conf(0.6);
    let mut miner = StreamingMiner::new(ctx, cfg);

    let start = std::time::Instant::now();
    let (mut itemsets, mut rules) = (0, 0);
    while let Some(batch) = source.next_batch() {
        if let Some(snap) = miner.push_batch(batch)? {
            if !quiet && snap.batch_id % 8 == 7 {
                println!("  {}", snap.summary());
            }
            itemsets = snap.frequents.len();
            rules = snap.rules.len();
            if !quiet && snap.batch_id + 1 == BATCHES as u64 {
                println!("\n  strongest rules in the final window:");
                for r in snap.rules.iter().take(5) {
                    println!("    {r}");
                }
            }
        }
    }
    Ok((start.elapsed(), itemsets, rules))
}

fn main() -> rdd_eclat::error::Result<()> {
    println!(
        "drifting clickstream: {} batches x {BATCH} sessions, window {WINDOW} slide {SLIDE}\n",
        BATCHES
    );

    println!("incremental (delta re-mining + snapshot reuse):");
    let (inc_wall, inc_itemsets, inc_rules) = drive(MineMode::Incremental, false)?;
    println!(
        "\n  -> {} emissions-worth of mining in {} ({inc_itemsets} itemsets, {inc_rules} rules \
         in the final window)\n",
        BATCHES - SLIDE + 1,
        fmt_duration(inc_wall)
    );

    println!("from-scratch per batch (SeqEclat over the materialized window):");
    let (scratch_wall, scratch_itemsets, _) = drive(MineMode::FromScratch, true)?;
    println!("  -> same stream in {}", fmt_duration(scratch_wall));

    assert_eq!(
        inc_itemsets, scratch_itemsets,
        "both modes must agree on the final window"
    );
    println!(
        "\nincremental / from-scratch wall ratio: {:.2}x",
        scratch_wall.as_secs_f64() / inc_wall.as_secs_f64().max(1e-9)
    );
    Ok(())
}
