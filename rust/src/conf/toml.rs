//! Minimal TOML-subset parser (sections, scalar key/values, comments).

use crate::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl Value {
    /// As string, when `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer, when `Int`.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// As float (accepts `Int` too).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool, when `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: `(section, key, value)` triples in file order.
/// Top-level keys carry an empty section name.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: Vec<(String, String, Value)>,
}

impl TomlDoc {
    /// Parse the subset; errors carry line numbers.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut entries = Vec::new();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(Error::parse(format!("line {}: expected key = value", no + 1)));
            };
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(Error::parse(format!("line {}: empty key", no + 1)));
            }
            let value = parse_value(value.trim())
                .ok_or_else(|| Error::parse(format!("line {}: bad value {value:?}", no + 1)))?;
            entries.push((section.clone(), key, value));
        }
        Ok(TomlDoc { entries })
    }

    /// Iterate `(section, key, value)`.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    /// Look up a key, optionally within a section.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Option<Value> {
    if let Some(stripped) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Some(Value::Str(stripped.to_string()));
    }
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Some(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Some(Value::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_kinds() {
        let doc = TomlDoc::parse(
            "a = \"s\"\nb = 3\nc = 0.5\nd = true\ne = false\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Str("s".into())));
        assert_eq!(doc.get("", "b"), Some(&Value::Int(3)));
        assert_eq!(doc.get("", "c"), Some(&Value::Float(0.5)));
        assert_eq!(doc.get("", "d"), Some(&Value::Bool(true)));
        assert_eq!(doc.get("", "e"), Some(&Value::Bool(false)));
    }

    #[test]
    fn sections_and_comments() {
        let doc = TomlDoc::parse(
            "# top\nx = 1\n[s1] # side\ny = 2\n[s2]\nz = \"a # not comment\"\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "x"), Some(&Value::Int(1)));
        assert_eq!(doc.get("s1", "y"), Some(&Value::Int(2)));
        assert_eq!(doc.get("s2", "z"), Some(&Value::Str("a # not comment".into())));
        assert_eq!(doc.get("s1", "x"), None);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("good = 1\nbad line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = TomlDoc::parse("k = @@\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = TomlDoc::parse("i = 2\nf = 2.0\n").unwrap();
        assert_eq!(doc.get("", "i").unwrap().as_int(), Some(2));
        assert_eq!(doc.get("", "f").unwrap().as_int(), None);
        assert_eq!(doc.get("", "f").unwrap().as_f64(), Some(2.0));
        assert_eq!(doc.get("", "i").unwrap().as_f64(), Some(2.0));
    }
}
