//! Core item/itemset types shared across the FIM stack.

/// An item identifier. Datasets map their vocabulary to dense `u32`s.
pub type Item = u32;

/// A transaction identifier.
pub type Tid = u32;

/// An itemset: items sorted ascending, no duplicates.
pub type ItemSet = Vec<Item>;

/// A mined frequent itemset with its support count.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Frequent {
    /// The itemset (sorted ascending).
    pub items: ItemSet,
    /// Number of transactions containing it.
    pub support: u32,
}

impl Frequent {
    /// Construct, asserting sortedness in debug builds.
    pub fn new(items: ItemSet, support: u32) -> Self {
        debug_assert!(items.windows(2).all(|w| w[0] < w[1]), "itemset not sorted/unique: {items:?}");
        Frequent { items, support }
    }
}

impl std::fmt::Display for Frequent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, item) in self.items.iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{item}")?;
        }
        write!(f, " #SUP: {}", self.support)
    }
}

/// Minimum support threshold — either an absolute transaction count or a
/// fraction of the database size (the paper quotes fractions like 0.01).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MinSup {
    /// Absolute count of transactions.
    Count(u32),
    /// Fraction of the database size, in `(0, 1]`.
    Fraction(f64),
}

impl MinSup {
    /// Absolute-count threshold.
    pub fn count(c: u32) -> MinSup {
        MinSup::Count(c)
    }

    /// Relative threshold.
    pub fn fraction(f: f64) -> MinSup {
        assert!(f > 0.0 && f <= 1.0, "min_sup fraction out of range: {f}");
        MinSup::Fraction(f)
    }

    /// Resolve to an absolute count for a database of `n` transactions.
    /// Fractions round up (an itemset must appear in at least ⌈f·n⌉
    /// transactions), with a floor of 1.
    pub fn to_count(self, n: usize) -> u32 {
        match self {
            MinSup::Count(c) => c.max(1),
            MinSup::Fraction(f) => ((f * n as f64).ceil() as u32).max(1),
        }
    }
}

/// Join two sorted itemsets sharing all but their last item (the classic
/// Apriori/Eclat k-itemset join): `{p, a} ⋈ {p, b} = {p, a, b}` for a<b.
/// Returns `None` when prefixes differ.
pub fn prefix_join(a: &[Item], b: &[Item]) -> Option<ItemSet> {
    if a.len() != b.len() || a.is_empty() {
        return None;
    }
    let k = a.len() - 1;
    if a[..k] != b[..k] || a[k] >= b[k] {
        return None;
    }
    let mut out = Vec::with_capacity(a.len() + 1);
    out.extend_from_slice(a);
    out.push(b[k]);
    Some(out)
}

/// True when `needle` ⊆ `haystack`; both sorted ascending.
pub fn is_subset(needle: &[Item], haystack: &[Item]) -> bool {
    let mut it = haystack.iter();
    'outer: for &n in needle {
        for &h in it.by_ref() {
            if h == n {
                continue 'outer;
            }
            if h > n {
                return false;
            }
        }
        return false;
    }
    true
}

/// Canonical sort for mined results: by length, then lexicographically.
pub fn sort_frequents(items: &mut [Frequent]) {
    items.sort_by(|a, b| a.items.len().cmp(&b.items.len()).then_with(|| a.items.cmp(&b.items)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn min_sup_resolution() {
        assert_eq!(MinSup::count(5).to_count(100), 5);
        assert_eq!(MinSup::fraction(0.05).to_count(100), 5);
        assert_eq!(MinSup::fraction(0.001).to_count(100), 1);
        // Ceil: 0.025 * 100 = 2.5 -> 3
        assert_eq!(MinSup::fraction(0.025).to_count(100), 3);
        assert_eq!(MinSup::count(0).to_count(10), 1);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn min_sup_fraction_validated() {
        MinSup::fraction(1.5);
    }

    #[test]
    fn prefix_join_rules() {
        assert_eq!(prefix_join(&[1, 2], &[1, 3]), Some(vec![1, 2, 3]));
        assert_eq!(prefix_join(&[1, 3], &[1, 2]), None, "order matters");
        assert_eq!(prefix_join(&[1, 2], &[2, 3]), None, "prefix differs");
        assert_eq!(prefix_join(&[1], &[2]), Some(vec![1, 2]));
        assert_eq!(prefix_join(&[], &[]), None);
        assert_eq!(prefix_join(&[1, 2], &[1, 2]), None, "equal last items");
    }

    #[test]
    fn subset_checks() {
        assert!(is_subset(&[2, 5], &[1, 2, 3, 5, 8]));
        assert!(!is_subset(&[2, 6], &[1, 2, 3, 5, 8]));
        assert!(is_subset(&[], &[1]));
        assert!(!is_subset(&[1], &[]));
        assert!(is_subset(&[1, 2, 3], &[1, 2, 3]));
    }

    #[test]
    fn display_matches_spmf_style() {
        let f = Frequent::new(vec![3, 7], 42);
        assert_eq!(f.to_string(), "3 7 #SUP: 42");
    }

    #[test]
    fn sort_frequents_by_len_then_lex() {
        let mut v = vec![
            Frequent::new(vec![2], 5),
            Frequent::new(vec![1, 2], 3),
            Frequent::new(vec![1], 9),
            Frequent::new(vec![1, 3], 2),
        ];
        sort_frequents(&mut v);
        let shapes: Vec<&[Item]> = v.iter().map(|f| f.items.as_slice()).collect();
        assert_eq!(shapes, vec![&[1][..], &[2], &[1, 2], &[1, 3]]);
    }
}
