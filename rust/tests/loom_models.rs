//! Loom model checking for the crate's hand-rolled concurrent
//! structures (PR 9). Compiled only under `RUSTFLAGS="--cfg loom"`:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom_models
//! ```
//!
//! Every structure here is built on the [`rdd_eclat::sync`] shim, so
//! under `--cfg loom` these models drive the *production* code paths
//! with loom's exhaustive scheduler — every interleaving up to the
//! preemption bound is executed, and each `assert!` must hold in all of
//! them. Internal-state models (reader pins on the double-buffer slots,
//! the span `EventRing`) live next to their modules in
//! `#[cfg(all(loom, test))]` unit mods; this file checks the public
//! APIs: metric cells, the shuffle store, the thread pool, and the
//! snapshot pipe.

#![cfg(loom)]

use loom::sync::atomic::{AtomicUsize, Ordering};
use loom::sync::Arc;
use loom::thread;

use rdd_eclat::engine::pool::ThreadPool;
use rdd_eclat::engine::{ShuffleId, ShuffleStore};
use rdd_eclat::fim::Frequent;
use rdd_eclat::obs::{Counter, Gauge, Histogram};
use rdd_eclat::stream::{snapshot_pipe, BatchSnapshot, MinePlan};

/// Run `f` under loom with the suite's standard bounds. A preemption
/// bound of 3 is loom's recommended sweet spot: every bug class the
/// models target (torn publish, lost wakeup, dropped count) needs at
/// most a couple of forced preemptions to surface, and the bound keeps
/// the state space tractable.
fn model(f: impl Fn() + Sync + Send + 'static) {
    let mut builder = loom::model::Builder::new();
    builder.preemption_bound = Some(3);
    builder.max_branches = 100_000;
    builder.check(f);
}

/// A self-consistent synthetic snapshot: `window_txns` is a function of
/// `batch_id`, so any torn read shows up as an inconsistent pair.
fn snap(k: u64) -> BatchSnapshot {
    BatchSnapshot {
        batch_id: k,
        window_txns: (k as usize) * 3 + 1,
        window_batches: 1,
        min_sup_count: 1,
        frequent_items: 1,
        dirty_frequent_items: 0,
        plan: MinePlan::Rebuild,
        frequents: vec![Frequent::new(vec![k as u32], k as u32 + 1)],
        rules: Vec::new(),
        wall: std::time::Duration::ZERO,
    }
}

// ---------------------------------------------------------------- obs

/// Referenced by the `// ordering:` comment on `Counter::incr`: relaxed
/// RMWs alone keep concurrent increments exact.
#[test]
fn loom_counter_concurrent_increments_exact() {
    model(|| {
        let c = Arc::new(Counter::new());
        let threads: Vec<_> = (0..2)
            .map(|_| {
                let c = Arc::clone(&c);
                thread::spawn(move || {
                    c.incr(1);
                    c.incr(1);
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), 4, "no increment may be lost in any interleaving");
    });
}

/// Referenced by the `// ordering:` comment on `Gauge::add`: the
/// high-water mark is a monotone max-fold — no interleaving of relaxed
/// RMW + max can under-report the peak level.
#[test]
fn loom_gauge_high_water_is_monotone_max() {
    model(|| {
        let g = Arc::new(Gauge::new());
        let a = {
            let g = Arc::clone(&g);
            thread::spawn(move || {
                g.add(2);
                g.add(-2);
            })
        };
        let b = {
            let g = Arc::clone(&g);
            thread::spawn(move || g.add(1))
        };
        a.join().unwrap();
        b.join().unwrap();
        assert_eq!(g.get(), 1, "level is the sum of all deltas");
        let hw = g.high_water();
        // Thread A's first add alone reaches level >= 2; with B's +1
        // interleaved before it the peak is 3. Any hw outside [2, 3]
        // means a max-fold was lost or invented.
        assert!((2..=3).contains(&hw), "high-water {hw} outside the reachable peaks");
    });
}

/// Referenced by the `// ordering:` comment on `Histogram::record`:
/// bucket/count/sum/max stay exact under concurrent recording.
#[test]
fn loom_histogram_concurrent_records_exact() {
    model(|| {
        let h = Arc::new(Histogram::new());
        let a = {
            let h = Arc::clone(&h);
            thread::spawn(move || h.record(3))
        };
        let b = {
            let h = Arc::clone(&h);
            thread::spawn(move || h.record(100))
        };
        a.join().unwrap();
        b.join().unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 103);
        assert_eq!(h.max(), 100);
    });
}

// ------------------------------------------------------------- engine

/// Referenced by the `// ordering:` comment in `ShuffleStore::put`:
/// the relaxed traffic tallies stay exact under concurrent map-task
/// writes, and the buckets themselves are published by the `RwLock`.
#[test]
fn loom_shuffle_concurrent_puts_tally_exactly() {
    model(|| {
        let store = Arc::new(ShuffleStore::new());
        let id = ShuffleId(0);
        let a = {
            let store = Arc::clone(&store);
            thread::spawn(move || store.put(id, 0, 0, vec![1u32, 2]))
        };
        let b = {
            let store = Arc::clone(&store);
            thread::spawn(move || store.put(id, 1, 0, vec![3u32]))
        };
        a.join().unwrap();
        b.join().unwrap();
        let (records, bytes) = store.traffic();
        assert_eq!(records, 3, "record tally lost an RMW");
        assert_eq!(bytes, 12, "byte tally lost an RMW");
        assert_eq!(store.len(), 2);
        let merged: Vec<u32> = store.fetch(id, 2, 0).unwrap();
        assert_eq!(merged, vec![1, 2, 3], "map-order concatenation");
    });
}

/// `execute` racing `close` (the `&self` half of shutdown) admits
/// exactly two outcomes: the job is accepted and then *guaranteed* to
/// run (workers drain the queue before exiting), or it is cleanly
/// rejected. Never accepted-and-dropped, never run twice.
#[test]
fn loom_pool_execute_vs_close_job_runs_iff_accepted() {
    model(|| {
        let ran = Arc::new(AtomicUsize::new(0));
        let pool = Arc::new(ThreadPool::new(1));
        let submitter = {
            let pool = Arc::clone(&pool);
            let ran = Arc::clone(&ran);
            thread::spawn(move || {
                pool.execute(move || {
                    // ordering: Relaxed — single observer, after join.
                    ran.fetch_add(1, Ordering::Relaxed);
                })
                .is_ok()
            })
        };
        pool.close();
        let accepted = submitter.join().unwrap();
        // Last Arc: drop runs shutdown, joining the worker.
        drop(pool);
        // ordering: Relaxed — the worker is joined; read is sequential.
        let runs = ran.load(Ordering::Relaxed);
        if accepted {
            assert_eq!(runs, 1, "accepted job must run exactly once");
        } else {
            assert_eq!(runs, 0, "rejected job must never run");
        }
    });
}

/// Dropping the pool (implicit shutdown) drains every queued job.
#[test]
fn loom_pool_drop_drains_queued_jobs() {
    model(|| {
        let ran = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(1);
            for _ in 0..2 {
                let ran = Arc::clone(&ran);
                pool.execute(move || {
                    // ordering: Relaxed — single observer after join.
                    ran.fetch_add(1, Ordering::Relaxed);
                })
                .expect("pool is open");
            }
        } // drop == shutdown: close, drain, join
        // ordering: Relaxed — workers are joined; this is sequential.
        assert_eq!(ran.load(Ordering::Relaxed), 2, "drop may not drop queued jobs");
    });
}

/// Idempotent shutdown: a second shutdown (and the drop after it) is a
/// no-op, and execute-after-shutdown reliably errors.
#[test]
fn loom_pool_shutdown_is_idempotent_and_closes_submission() {
    model(|| {
        let mut pool = ThreadPool::new(1);
        pool.shutdown();
        pool.shutdown();
        assert!(pool.execute(|| ()).is_err(), "closed pool must reject jobs");
    });
}

// ------------------------------------------------------------- stream

/// Public-API end of the double-buffer protocol: a reader races two
/// publishes. Every observed snapshot must be internally consistent
/// (no torn `ServingSnapshot`) and the sequence a single reader sees
/// must be monotone in `batch_id`.
#[test]
fn loom_serve_reader_sees_consistent_monotone_snapshots() {
    model(|| {
        let (mut publisher, handle) = snapshot_pipe();
        let reader = {
            let handle = handle.clone();
            thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2 {
                    if let Some(s) = handle.latest() {
                        assert_eq!(
                            s.window_txns,
                            (s.batch_id as usize) * 3 + 1,
                            "torn snapshot: fields from different publishes"
                        );
                        assert!(s.batch_id >= last, "reader went back in time");
                        last = s.batch_id;
                    }
                }
            })
        };
        publisher.publish(snap(1));
        publisher.publish(snap(2));
        reader.join().unwrap();
        let final_snap = handle.latest().expect("two publishes happened");
        assert_eq!(final_snap.batch_id, 2, "last publish wins");
        assert_eq!(handle.version(), 2);
    });
}
