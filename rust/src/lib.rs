//! # RDD-Eclat
//!
//! A production-quality reproduction of *"RDD-Eclat: Approaches to
//! Parallelize Eclat Algorithm on Spark RDD Framework"* (Singh, Singh,
//! Mishra, Garg — ICCNCT 2019 / extended 2021).
//!
//! The crate is organised as three layers:
//!
//! * [`engine`] — a from-scratch Spark-like RDD engine (the substrate the
//!   paper's algorithms run on): lazy RDDs with narrow/shuffle
//!   dependencies, a DAG → stage → task scheduler over an own thread pool,
//!   hash shuffle, broadcast variables, accumulators, partition caching,
//!   lineage-based recomputation with fault injection, per-task metrics,
//!   and a virtual-cluster makespan simulator used for core-scaling
//!   studies on small machines.
//! * [`fim`] — frequent-itemset-mining primitives: horizontal/vertical
//!   databases, packed tidset bitmaps, the triangular matrix of
//!   candidate-2-itemset counts, prefix tries, equivalence classes, the
//!   bottom-up Eclat recursion, Apriori candidate generation, FP-Growth,
//!   and association-rule generation.
//! * [`algorithms`] — the paper's contribution: the five RDD-Eclat
//!   variants (`EclatV1`..`EclatV5`), the YAFIM-style RDD-Apriori
//!   baseline, and sequential oracles used for correctness testing.
//!
//! Supporting layers: [`data`] (benchmark dataset generators matching the
//! paper's Table 2), [`runtime`] (PJRT execution of AOT-compiled
//! JAX/Pallas artifacts for the support-counting hot spot), [`bench`] (a
//! small criterion-like measurement harness), [`conf`]/[`cli`]
//! (configuration + launcher), [`figures`] (drivers that regenerate
//! every table and figure of the paper's evaluation), and [`stream`]
//! (DStream-style micro-batch mining: sliding windows over an
//! incrementally maintained vertical store, with per-batch frequent
//! itemset and association-rule snapshots, an async ingest service, and
//! a lock-free-read snapshot serving layer). [`net`] moves the
//! streaming shards out of the process: a versioned CRC-guarded wire
//! format plus a blocking framed TCP transport (`repro shard-worker`
//! hosts shard replicas, `repro stream --workers` drives them with the
//! same apply/mine surface as the in-process store). [`obs`] is the
//! observability spine: a lock-free metrics registry, RAII span tracing
//! across every layer, and a Chrome-trace exporter (`repro ... --trace
//! out.trace.json`, load in Perfetto). [`sync`] is the loom-aware
//! synchronization shim every hand-rolled concurrent structure is built
//! on; together with the loom model suite, the Miri/TSan CI jobs and
//! the crate lint (`cargo run --bin lint`) it forms the concurrency
//! correctness layer (see README "Correctness tooling").
//!
//! ## Quickstart
//!
//! Mining goes through the unified façade: pick a [`algorithms::Variant`]
//! from the registry and run it in a [`algorithms::MiningSession`]:
//!
//! ```
//! use rdd_eclat::prelude::*;
//!
//! // A tiny in-memory transaction database.
//! let db = Database::from_rows(vec![
//!     vec![1, 2, 3],
//!     vec![1, 2],
//!     vec![2, 3],
//!     vec![1, 2, 3, 4],
//! ]);
//! let ctx = ClusterContext::builder().cores(2).build();
//! let result = MiningSession::on(&ctx)
//!     .db(&db)
//!     .min_sup(MinSup::count(2))
//!     .run(Variant::V4)
//!     .unwrap();
//! assert!(result.contains(&[1, 2], 3));
//! assert!(result.contains(&[1, 2, 3], 2));
//! ```
//!
//! Mining paths emit through pluggable [`fim::FrequentSink`]s — collect
//! to a `Vec<Frequent>` (the default), pool into a flat zero-allocation
//! arena ([`fim::PooledSink`]), keep only the strongest patterns
//! ([`fim::TopKSink`]), or just count ([`fim::CountSink`]):
//!
//! ```
//! use rdd_eclat::prelude::*;
//!
//! let db = Database::from_rows(vec![vec![1, 2], vec![1, 2, 3], vec![2, 3]]);
//! let mut top = TopKSink::new(2);
//! SeqEclat::mine_into(&db, MinSup::count(2), &mut top);
//! let strongest = top.into_sorted();
//! assert_eq!(strongest[0], Frequent::new(vec![2], 3));
//! ```

pub mod algorithms;
pub mod bench;
pub mod cli;
pub mod conf;
pub mod data;
pub mod engine;
pub mod error;
pub mod figures;
pub mod fim;
pub mod net;
pub mod obs;
pub mod runtime;
pub mod stream;
pub mod sync;
pub mod util;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::algorithms::{
        Algorithm, EclatOptions, EclatV1, EclatV2, EclatV3, EclatV4, EclatV5, FimResult,
        MiningSession, RddApriori, SeqApriori, SeqEclat, SeqEclatDiffset, SeqFpGrowth, Variant,
    };
    pub use crate::conf::EclatConfig;
    pub use crate::data::{Database, DatasetSpec};
    pub use crate::engine::{ChaosPolicy, ClusterContext, Rdd, SchedulerConfig};
    pub use crate::error::{Error, Result};
    pub use crate::fim::{
        generate_rules, sort_frequents, CollectSink, CountSink, Frequent, FrequentSink, Item,
        ItemSet, MinSup, PooledSink, Tid, TopKSink,
    };
    pub use crate::net::{RemoteShardSet, ShardWorker};
    pub use crate::obs::{self, MetricsSnapshot, SpanGuard};
    pub use crate::stream::{
        BatchSnapshot, BatchSource, IngestConfig, IngestStats, MineMode, ServingSnapshot,
        ShardLoad, ShardStats, ShardedVerticalDb, SnapshotHandle, StreamConfig, StreamService,
        StreamingMiner, WindowSpec,
    };
}
