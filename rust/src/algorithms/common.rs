//! Shared phase implementations for the RDD-Eclat variants.
//!
//! Each function transcribes one phase of the paper's pseudo code
//! (Algorithms 2–9) onto the engine. Variants compose these differently;
//! see the per-variant modules for the exact pipelines.

use std::collections::HashMap;
use std::sync::Arc;

use crate::engine::{ClusterContext, Partitioner, Rdd};
use crate::error::Result;
use crate::fim::{
    construct_classes, AutoScratch, Database, FrequentSink, Item, PooledSink, Tid, Tidset,
    TriMatrix, VerticalDb,
};

use super::{CoocStrategy, TriMatrixProvider};

/// Native (loop-based) [`TriMatrixProvider`] — the default per-partition
/// compute inside the accumulator strategy, and the baseline side of the
/// A4 native-vs-XLA ablation.
pub struct NativeCooc;

impl TriMatrixProvider for NativeCooc {
    fn compute(&self, transactions: &[Vec<Item>], max_item: Item) -> Result<TriMatrix> {
        let mut m = TriMatrix::new(max_item);
        for t in transactions {
            m.update_transaction(t);
        }
        Ok(m)
    }
}

/// Create the transactions RDD from a parsed database (the `textFile` +
/// split step of the paper collapsed: parsing happened at load).
pub fn transactions_rdd(ctx: &ClusterContext, db: &Database, parts: usize) -> Rdd<Vec<Item>> {
    ctx.parallelize(db.transactions().to_vec(), parts)
}

/// Phase-1 of EclatV2/V3 (Algorithm 5): word-count frequent items.
/// Returns `(item, support)` sorted by item id (the paper's
/// "alphanumeric" order).
pub fn phase1_wordcount(
    ctx: &ClusterContext,
    transactions: &Rdd<Vec<Item>>,
    min_sup: u32,
) -> Result<Vec<(Item, u32)>> {
    let par = ctx.default_parallelism();
    let mut freq: Vec<(Item, u32)> = transactions
        .flat_map(|t| t)
        .map(|item| (item, 1u32))
        .reduce_by_key(par, |a, b| a + b)
        .filter(move |(_, c)| *c >= min_sup)
        .collect()?;
    freq.sort_unstable();
    Ok(freq)
}

/// Phase-1 of EclatV1 (Algorithm 2): build `(item, tidset)` via
/// `flatMapToPair` + `groupByKey`, filter by support, collect and sort
/// ascending by support. Returns the vertical list.
///
/// The paper collapses the database to **one** partition so tids stay
/// globally consistent — its acknowledged scalability bottleneck. Here
/// the same global tid assignment is obtained over the full
/// `default_parallelism` partitioning: one cheap sizing job yields the
/// per-partition element counts, their prefix sums become per-partition
/// tid offsets (the `zipWithIndex` construction), and every partition
/// then emits `(item, offset + local index)` pairs in parallel. The
/// resulting vertical database is identical to the single-partition
/// build.
pub fn phase1_group_by_key(
    ctx: &ClusterContext,
    db: &Database,
    min_sup: u32,
) -> Result<Vec<(Item, Tidset)>> {
    let par = ctx.default_parallelism();
    let transactions = transactions_rdd(ctx, db, par);
    // Prefix sums of partition sizes -> globally consistent tid offsets.
    let sizes = transactions.partition_sizes()?;
    let mut offsets: Vec<Tid> = vec![0; sizes.len()];
    let mut acc: Tid = 0;
    for (i, s) in sizes.iter().enumerate() {
        offsets[i] = acc;
        acc += *s as Tid;
    }
    let pairs: Rdd<(Item, Tid)> = transactions.map_partitions_with_index(move |idx, txns| {
        let base = offsets[idx];
        let mut out = Vec::new();
        for (local, t) in txns.into_iter().enumerate() {
            let tid = base + local as Tid;
            for item in t {
                out.push((item, tid));
            }
        }
        out
    });
    let mut vertical: Vec<(Item, Tidset)> = pairs
        .group_by_key(par)
        .filter(move |(_, tids)| tids.len() as u32 >= min_sup)
        .collect()?;
    for (_, tids) in &mut vertical {
        tids.sort_unstable();
    }
    // Ascending support, item id tie-break — the paper's total order.
    vertical.sort_by(|a, b| a.1.len().cmp(&b.1.len()).then_with(|| a.0.cmp(&b.0)));
    Ok(vertical)
}

/// Phase-2 (Algorithm 3/6): compute the triangular matrix of candidate
/// 2-itemset counts over `transactions`, either through a per-partition
/// accumulator (the paper) or a pluggable provider (XLA backend).
pub fn phase2_trimatrix(
    ctx: &ClusterContext,
    transactions: &Rdd<Vec<Item>>,
    max_item: Item,
    strategy: &CoocStrategy,
) -> Result<TriMatrix> {
    match strategy {
        CoocStrategy::Accumulator => {
            let acc = ctx.accumulator(TriMatrix::new(max_item), |a: &mut TriMatrix, b: TriMatrix| {
                a.merge(&b)
            });
            let task_acc = acc.clone();
            transactions
                .map_partitions_with_index(move |_idx, txns| {
                    let mut local = TriMatrix::new(max_item);
                    for t in &txns {
                        local.update_transaction(t);
                    }
                    task_acc.add(local);
                    Vec::<()>::new()
                })
                .run()?;
            Ok(acc.take(TriMatrix::new(0)))
        }
        CoocStrategy::Provider(provider) => {
            let acc = ctx.accumulator(TriMatrix::new(max_item), |a: &mut TriMatrix, b: TriMatrix| {
                a.merge(&b)
            });
            let task_acc = acc.clone();
            let provider: Arc<dyn TriMatrixProvider> = Arc::clone(provider);
            transactions
                .map_partitions_with_index(move |_idx, txns| {
                    let local = provider
                        .compute(&txns, max_item)
                        .expect("cooc provider failed in task");
                    task_acc.add(local);
                    Vec::<()>::new()
                })
                .run()?;
            Ok(acc.take(TriMatrix::new(0)))
        }
    }
}

/// Phase-3 of EclatV2 (Algorithm 7): vertical dataset from the filtered
/// transactions via `coalesce(1)` + `flatMapToPair` + `groupByKey`.
/// Returns the `(item, tidset)` list sorted ascending by support.
pub fn phase3_vertical_grouped(
    ctx: &ClusterContext,
    filtered: &Rdd<Vec<Item>>,
) -> Result<Vec<(Item, Tidset)>> {
    let par = ctx.default_parallelism();
    let single = filtered.coalesce(1);
    let pairs: Rdd<(Item, Tid)> = single.map_partitions_with_index(|_idx, txns| {
        let mut out = Vec::new();
        for (tid, t) in txns.into_iter().enumerate() {
            for item in t {
                out.push((item, tid as Tid));
            }
        }
        out
    });
    let mut vertical: Vec<(Item, Tidset)> = pairs.group_by_key(par).collect()?;
    for (_, tids) in &mut vertical {
        tids.sort_unstable();
    }
    vertical.sort_by(|a, b| a.1.len().cmp(&b.1.len()).then_with(|| a.0.cmp(&b.0)));
    Ok(vertical)
}

/// Phase-3 of EclatV3 (Algorithm 8): vertical dataset accumulated into a
/// shared hashmap accumulator (`accMap`) instead of shuffling.
pub fn phase3_vertical_accumulated(
    ctx: &ClusterContext,
    filtered: &Rdd<Vec<Item>>,
) -> Result<Vec<(Item, Tidset)>> {
    type TidMap = HashMap<Item, Tidset>;
    let acc = ctx.accumulator(TidMap::new(), |a: &mut TidMap, b: TidMap| {
        for (k, mut v) in b {
            a.entry(k).or_default().append(&mut v);
        }
    });
    let task_acc = acc.clone();
    filtered
        .coalesce(1)
        .map_partitions_with_index(move |_idx, txns| {
            let mut local = TidMap::new();
            for (tid, t) in txns.into_iter().enumerate() {
                for item in t {
                    local.entry(item).or_default().push(tid as Tid);
                }
            }
            task_acc.add(local);
            Vec::<()>::new()
        })
        .run()?;
    let map = acc.take(TidMap::new());
    let mut vertical: Vec<(Item, Tidset)> = map.into_iter().collect();
    for (_, tids) in &mut vertical {
        tids.sort_unstable();
    }
    vertical.sort_by(|a, b| a.1.len().cmp(&b.1.len()).then_with(|| a.0.cmp(&b.0)));
    Ok(vertical)
}

/// Phase-3 of EclatV1 / Phase-4 of V2–V5 (Algorithm 4/9): build the
/// 1-prefix equivalence classes from the vertical list (with optional
/// triangular-matrix pruning), key each class by its dense prefix index
/// `v`, `partitionBy` the given partitioner, cache, and mine every class
/// with the bottom-up recursion, emitting into `out`.
///
/// Each mining task owns one [`AutoScratch`] arena *and* one
/// [`PooledSink`] for its whole partition: within a task, mining
/// allocates nothing per candidate or per emission in steady state —
/// the partition ships a single flat pool back, and the pools are
/// replayed into the caller's sink driver-side. Returns the class
/// members routed to each partition (the §4.5 workload measure).
pub fn mine_equivalence_classes<S: FrequentSink + ?Sized>(
    ctx: &ClusterContext,
    vertical: Vec<(Item, Tidset)>,
    universe: usize,
    min_sup: u32,
    tri: Option<&TriMatrix>,
    partitioner: Arc<dyn Partitioner<usize>>,
    out: &mut S,
) -> Result<Vec<usize>> {
    let vdb = VerticalDb { items: vertical, universe };
    let index_of: HashMap<Item, usize> =
        vdb.items.iter().enumerate().map(|(i, (item, _))| (*item, i)).collect();
    let classes = construct_classes(&vdb, min_sup, tri);

    // Driver-side load accounting (cheap; mirrors what the partitioner
    // will do so the harness can report balance).
    let mut loads = vec![0usize; partitioner.num_partitions()];
    let keyed: Vec<(usize, crate::fim::EqClass)> = classes
        .into_iter()
        .map(|c| {
            let v = index_of[&c.prefix];
            loads[partitioner.partition(&v)] += c.weight();
            (v, c)
        })
        .collect();

    // Initial partition count is irrelevant: partitionBy immediately
    // redistributes by class key (paper Algorithm 4 line 17–18).
    let ecs = ctx.parallelize(keyed, 1).partition_by(partitioner).cache();
    let pools: Vec<PooledSink> = ecs
        .map_partitions_with_index(move |_idx, classes| {
            let mut scratch = AutoScratch::new();
            let mut pool = PooledSink::new();
            for (_, ec) in classes {
                ec.mine_auto_into(&mut scratch, min_sup, universe, &mut pool);
            }
            vec![pool]
        })
        .collect()?;
    for pool in &pools {
        pool.replay(out);
    }
    Ok(loads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::partitioners::DefaultClassPartitioner;
    use crate::fim::sort_frequents;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    fn ctx() -> ClusterContext {
        ClusterContext::builder().cores(2).build()
    }

    #[test]
    fn wordcount_matches_bruteforce() {
        let ctx = ctx();
        let db = demo_db();
        let txns = transactions_rdd(&ctx, &db, 3);
        let freq = phase1_wordcount(&ctx, &txns, 3).unwrap();
        assert_eq!(freq, vec![(1, 3), (2, 4), (3, 5), (5, 5)]);
    }

    #[test]
    fn groupbykey_phase1_builds_sorted_vertical() {
        let ctx = ctx();
        let db = demo_db();
        let v = phase1_group_by_key(&ctx, &db, 3).unwrap();
        let items: Vec<Item> = v.iter().map(|(i, _)| *i).collect();
        assert_eq!(items, vec![1, 2, 3, 5], "ascending support order");
        // Tidset of item 3: transactions 0,1,2,4,5.
        let t3 = &v.iter().find(|(i, _)| *i == 3).unwrap().1;
        assert_eq!(*t3, vec![0, 1, 2, 4, 5]);
    }

    #[test]
    fn trimatrix_accumulator_counts_pairs() {
        let ctx = ctx();
        let db = demo_db();
        let txns = transactions_rdd(&ctx, &db, 3);
        let m = phase2_trimatrix(&ctx, &txns, 5, &CoocStrategy::Accumulator).unwrap();
        assert_eq!(m.support(2, 5), 4);
        assert_eq!(m.support(3, 5), 4);
        assert_eq!(m.support(1, 3), 3);
        assert_eq!(m.support(1, 2), 1);
    }

    #[test]
    fn provider_strategy_equals_accumulator() {
        let ctx = ctx();
        let db = demo_db();
        let txns = transactions_rdd(&ctx, &db, 2);
        let a = phase2_trimatrix(&ctx, &txns, 5, &CoocStrategy::Accumulator).unwrap();
        let b = phase2_trimatrix(
            &ctx,
            &txns,
            5,
            &CoocStrategy::Provider(Arc::new(NativeCooc)),
        )
        .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn vertical_grouped_and_accumulated_agree() {
        let ctx = ctx();
        let db = demo_db();
        let txns = transactions_rdd(&ctx, &db, 3);
        let a = phase3_vertical_grouped(&ctx, &txns).unwrap();
        let b = phase3_vertical_accumulated(&ctx, &txns).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mine_classes_end_to_end() {
        let ctx = ctx();
        let db = demo_db();
        let vertical = phase1_group_by_key(&ctx, &db, 3).unwrap();
        let n = vertical.len();
        let mut got: Vec<crate::fim::Frequent> = Vec::new();
        let loads = mine_equivalence_classes(
            &ctx,
            vertical,
            db.len(),
            3,
            None,
            Arc::new(DefaultClassPartitioner::for_items(n)),
            &mut got,
        )
        .unwrap();
        sort_frequents(&mut got);
        let pairs: Vec<(Vec<Item>, u32)> =
            got.into_iter().map(|f| (f.items, f.support)).collect();
        assert_eq!(
            pairs,
            vec![
                (vec![1, 3], 3),
                (vec![2, 3], 3),
                (vec![2, 5], 4),
                (vec![3, 5], 4),
                (vec![2, 3, 5], 3),
            ]
        );
        // Class members: [1]->{3}, [2]->{3,5}, [3]->{5} = 4 atoms.
        assert_eq!(loads.iter().sum::<usize>(), 4);
    }

    #[test]
    fn mine_classes_pooled_emission_matches_collect_sink() {
        // The per-partition PooledSink path must agree with mining the
        // same classes straight into a collect sink.
        let ctx = ctx();
        let db = demo_db();
        for min_sup in 2..=4 {
            let vertical = phase1_group_by_key(&ctx, &db, min_sup).unwrap();
            let n = vertical.len();
            let mut via_rdd: Vec<crate::fim::Frequent> = Vec::new();
            mine_equivalence_classes(
                &ctx,
                vertical.clone(),
                db.len(),
                min_sup,
                None,
                Arc::new(DefaultClassPartitioner::for_items(n)),
                &mut via_rdd,
            )
            .unwrap();
            let vdb = VerticalDb { items: vertical, universe: db.len() };
            let mut direct: Vec<crate::fim::Frequent> = Vec::new();
            let mut scratch = AutoScratch::new();
            for class in construct_classes(&vdb, min_sup, None) {
                class.mine_auto_into(&mut scratch, min_sup, db.len(), &mut direct);
            }
            sort_frequents(&mut via_rdd);
            sort_frequents(&mut direct);
            assert_eq!(via_rdd, direct, "min_sup={min_sup}");
        }
    }
}
