//! XLA-backed triangular-matrix computation (DESIGN.md A4 ablation).
//!
//! Implements [`TriMatrixProvider`] on top of the AOT `cooc` artifact:
//! transactions are encoded as 0/1 f32 blocks of shape `(TILE_T,
//! TILE_I)`; for each row block and each (column-chunk, column-chunk)
//! pair, one PJRT call computes `A_ci^T · A_cj`, whose entries are
//! accumulated into the item-value-keyed [`TriMatrix`] the Eclat phases
//! consume. Equivalent by construction to the native loop provider
//! ([`crate::algorithms::common::NativeCooc`]) — the property tests
//! assert bit-equality.

use std::sync::Arc;

use crate::algorithms::TriMatrixProvider;
use crate::error::Result;
use crate::fim::{Item, TriMatrix};

use super::service::{HostBuffer, XlaService};

/// Row tile (transactions per block) — matches the AOT artifact shape.
pub const TILE_T: usize = 256;
/// Column tile (items per chunk) — matches the AOT artifact shape.
pub const TILE_I: usize = 128;

/// The PJRT-backed co-occurrence provider.
pub struct XlaCooc {
    svc: Arc<XlaService>,
    artifact: String,
}

impl XlaCooc {
    /// Wrap a running service (expects the default `cooc_256x128`
    /// artifact from `make artifacts`).
    pub fn new(svc: Arc<XlaService>) -> XlaCooc {
        XlaCooc { svc, artifact: format!("cooc_{TILE_T}x{TILE_I}") }
    }
}

impl TriMatrixProvider for XlaCooc {
    fn compute(&self, transactions: &[Vec<Item>], max_item: Item) -> Result<TriMatrix> {
        let mut tri = TriMatrix::new(max_item);
        let n_items = max_item as usize + 1;
        let n_chunks = n_items.div_ceil(TILE_I);
        let dims = vec![TILE_T as i64, TILE_I as i64];

        for row_block in transactions.chunks(TILE_T) {
            // Encode this row block once per column chunk.
            let mut chunks: Vec<Vec<f32>> = vec![vec![0f32; TILE_T * TILE_I]; n_chunks];
            for (r, t) in row_block.iter().enumerate() {
                for &item in t {
                    let (c, local) = ((item as usize) / TILE_I, (item as usize) % TILE_I);
                    chunks[c][r * TILE_I + local] = 1.0;
                }
            }
            // All chunk pairs ci <= cj (the upper block triangle).
            for ci in 0..n_chunks {
                for cj in ci..n_chunks {
                    let out = self.svc.execute(
                        &self.artifact,
                        vec![
                            HostBuffer::F32(chunks[ci].clone(), dims.clone()),
                            HostBuffer::F32(chunks[cj].clone(), dims.clone()),
                        ],
                    )?;
                    let c = out[0].as_f32()?;
                    for li in 0..TILE_I {
                        let gi = ci * TILE_I + li;
                        if gi >= n_items {
                            break;
                        }
                        for lj in 0..TILE_I {
                            let gj = cj * TILE_I + lj;
                            if gj >= n_items {
                                break;
                            }
                            if gi < gj {
                                let count = c[li * TILE_I + lj];
                                if count > 0.0 {
                                    tri.add_count(gi as Item, gj as Item, count as u32);
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(tri)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::common::NativeCooc;
    use crate::util::prng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn xla_cooc_matches_native_small() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = Arc::new(XlaService::start(dir).unwrap());
        let xla = XlaCooc::new(svc);
        let txns = vec![vec![0, 2, 5], vec![1, 2], vec![0, 2, 5], vec![5]];
        let a = xla.compute(&txns, 5).unwrap();
        let b = NativeCooc.compute(&txns, 5).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn xla_cooc_matches_native_multi_chunk() {
        let Some(dir) = artifacts_dir() else { return };
        // max_item 300 -> 3 column chunks; 600 transactions -> 3 row blocks.
        let svc = Arc::new(XlaService::start(dir).unwrap());
        let xla = XlaCooc::new(svc);
        let mut rng = Rng::new(5);
        let txns: Vec<Vec<Item>> = (0..600)
            .map(|_| {
                let mut t: Vec<Item> =
                    (0..rng.range(1, 12)).map(|_| rng.below(301) as Item).collect();
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        let a = xla.compute(&txns, 300).unwrap();
        let b = NativeCooc.compute(&txns, 300).unwrap();
        assert_eq!(a, b);
    }
}
