//! Sorted-vector tidsets and the vertical database.
//!
//! Eclat's vertical format (§2.1): `item → tidset(item)`. Tidsets here are
//! sorted `Vec<Tid>`; support is length; candidate support is intersection
//! size. The engine-level RDD-Eclat variants move these around as RDD
//! values, so they stay plain clonable vectors. The packed-bitmap
//! representation in [`super::bitmap`] is the optimized alternative used
//! by the bottom-up search once classes are local to a task.

use std::collections::HashMap;

use super::itemset::{Item, Tid};
use super::transaction::Database;

/// A sorted, de-duplicated list of transaction ids.
pub type Tidset = Vec<Tid>;

/// Intersect two sorted tidsets (linear merge; switches to galloping when
/// sizes are very skewed).
pub fn intersect(a: &[Tid], b: &[Tid]) -> Tidset {
    // Galloping pays when one side is ≥ ~8x smaller.
    if a.len() * 8 < b.len() {
        return gallop_intersect(a, b);
    }
    if b.len() * 8 < a.len() {
        return gallop_intersect(b, a);
    }
    let mut out = Vec::with_capacity(a.len().min(b.len()));
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

/// Intersection via binary search of the smaller side into the larger.
fn gallop_intersect(small: &[Tid], large: &[Tid]) -> Tidset {
    let mut out = Vec::with_capacity(small.len());
    let mut lo = 0usize;
    for &t in small {
        match large[lo..].binary_search(&t) {
            Ok(pos) => {
                out.push(t);
                lo += pos + 1;
            }
            Err(pos) => {
                lo += pos;
            }
        }
        if lo >= large.len() {
            break;
        }
    }
    out
}

/// Count-only galloping intersection: binary-search the smaller side
/// into the larger without materializing the result — skewed support
/// counting allocates nothing.
fn gallop_intersect_count(small: &[Tid], large: &[Tid]) -> u32 {
    let mut n = 0u32;
    let mut lo = 0usize;
    for &t in small {
        match large[lo..].binary_search(&t) {
            Ok(pos) => {
                n += 1;
                lo += pos + 1;
            }
            Err(pos) => {
                lo += pos;
            }
        }
        if lo >= large.len() {
            break;
        }
    }
    n
}

/// `|a ∩ b|` without materializing (support counting). Skewed sizes take
/// the count-only galloping path.
pub fn intersect_count(a: &[Tid], b: &[Tid]) -> u32 {
    if a.len() * 8 < b.len() {
        return gallop_intersect_count(a, b);
    }
    if b.len() * 8 < a.len() {
        return gallop_intersect_count(b, a);
    }
    let (mut i, mut j, mut n) = (0, 0, 0u32);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Set difference `a \ b` of sorted tidsets — the diffset representation
/// (Zaki's dEclat), an optional optimization ablated in the benches.
pub fn difference(a: &[Tid], b: &[Tid]) -> Tidset {
    let mut out = Vec::with_capacity(a.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            out.push(a[i]);
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
    out
}

/// The vertical database: frequent items with their tidsets, in a chosen
/// item order (the paper sorts by ascending support — the "total order"
/// that balances equivalence-class fan-out).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerticalDb {
    /// `(item, tidset)` pairs, in mining order.
    pub items: Vec<(Item, Tidset)>,
    /// Number of transactions in the underlying horizontal database.
    pub universe: usize,
}

impl VerticalDb {
    /// Build from a horizontal database, keeping only items with support
    /// ≥ `min_sup_count`, ordered by ascending support with item id as the
    /// tie-break (the order EclatV1 Phase-1 produces via
    /// `sort(freqItemTids.collect())`).
    pub fn build(db: &Database, min_sup_count: u32) -> VerticalDb {
        let mut tidsets: HashMap<Item, Tidset> = HashMap::new();
        for (tid, t) in db.transactions().iter().enumerate() {
            for &item in t {
                tidsets.entry(item).or_default().push(tid as Tid);
            }
        }
        let mut items: Vec<(Item, Tidset)> = tidsets
            .into_iter()
            .filter(|(_, tids)| tids.len() as u32 >= min_sup_count)
            .collect();
        items.sort_by(|a, b| a.1.len().cmp(&b.1.len()).then_with(|| a.0.cmp(&b.0)));
        VerticalDb { items, universe: db.len() }
    }

    /// Number of frequent items (`n` in the paper).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when no item is frequent.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The frequent items in mining order.
    pub fn item_order(&self) -> Vec<Item> {
        self.items.iter().map(|(i, _)| *i).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn intersect_basics() {
        assert_eq!(intersect(&[1, 3, 5, 7], &[3, 4, 5]), vec![3, 5]);
        assert_eq!(intersect(&[], &[1, 2]), Vec::<Tid>::new());
        assert_eq!(intersect_count(&[1, 3, 5, 7], &[3, 4, 5]), 2);
    }

    #[test]
    fn galloping_path_matches_linear() {
        let small = vec![5u32, 100, 900];
        let large: Vec<u32> = (0..1000).collect();
        assert_eq!(intersect(&small, &large), small);
        assert_eq!(intersect(&large, &small), small);
        assert_eq!(intersect_count(&small, &large), 3);
    }

    #[test]
    fn difference_basics() {
        assert_eq!(difference(&[1, 2, 3, 4], &[2, 4]), vec![1, 3]);
        assert_eq!(difference(&[1, 2], &[]), vec![1, 2]);
        assert_eq!(difference(&[], &[1]), Vec::<Tid>::new());
    }

    #[test]
    fn random_against_hashsets() {
        // Case 0..99: similar sizes (linear path); 100..199: heavily
        // skewed sizes so both galloping paths (materializing and
        // count-only) are exercised and must agree with the linear walk.
        let mut rng = Rng::new(9);
        for case in 0..200 {
            let skewed = case >= 100;
            let (n_a, n_b, universe) = if skewed {
                (rng.range(0, 6), rng.range(100, 300), 2000u64)
            } else {
                (rng.range(0, 80), rng.range(0, 80), 100u64)
            };
            let mut a: Vec<u32> = (0..n_a).map(|_| rng.below(universe) as u32).collect();
            let mut b: Vec<u32> = (0..n_b).map(|_| rng.below(universe) as u32).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let sa: std::collections::HashSet<_> = a.iter().copied().collect();
            let sb: std::collections::HashSet<_> = b.iter().copied().collect();
            let mut want: Vec<u32> = sa.intersection(&sb).copied().collect();
            want.sort_unstable();
            assert_eq!(intersect(&a, &b), want, "case {case}");
            assert_eq!(intersect(&b, &a), want, "case {case} swapped");
            // Count-only path (galloping when skewed) == linear walk.
            assert_eq!(intersect_count(&a, &b) as usize, want.len(), "case {case}");
            assert_eq!(intersect_count(&b, &a) as usize, want.len(), "case {case} swapped");
            let mut want_diff: Vec<u32> = sa.difference(&sb).copied().collect();
            want_diff.sort_unstable();
            assert_eq!(difference(&a, &b), want_diff, "case {case}");
        }
    }

    #[test]
    fn vertical_build_orders_by_support() {
        // item 1 in 3 txns, item 2 in 2, item 3 in 1, item 9 in 1.
        let db = Database::from_rows(vec![vec![1, 2], vec![1, 2, 3], vec![1, 9]]);
        let v = VerticalDb::build(&db, 2);
        assert_eq!(v.universe, 3);
        assert_eq!(v.item_order(), vec![2, 1], "ascending support");
        assert_eq!(v.items[0].1, vec![0, 1]);
        assert_eq!(v.items[1].1, vec![0, 1, 2]);
    }

    #[test]
    fn vertical_empty_when_nothing_frequent() {
        let db = Database::from_rows(vec![vec![1], vec![2]]);
        let v = VerticalDb::build(&db, 2);
        assert!(v.is_empty());
    }
}
