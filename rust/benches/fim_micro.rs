//! Micro-benchmarks of the FIM hot paths (criterion-style, own harness):
//! tidset vs bitmap intersection, triangular-matrix updates, bottom-up
//! recursion, candidate counting. These are the knobs the §Perf pass
//! tunes.
//!
//! Besides the CSV under `results/`, the run emits the perf-trajectory
//! file `BENCH_fim.json` at the repository root (override the path with
//! `BENCH_FIM_OUT`). Reproduce with:
//!
//! ```text
//! cargo bench --bench fim_micro          # SCALE=paper for full samples
//! ```

use rdd_eclat::bench::{black_box, Bench, Report};
use rdd_eclat::fim::{
    bottom_up, intersect, intersect_count, CandidateTrie, TidBitmap, Tidset, TriMatrix,
};
use rdd_eclat::util::prng::Rng;

fn random_tidset(rng: &mut Rng, universe: usize, density: f64) -> Tidset {
    (0..universe as u32).filter(|_| rng.chance(density)).collect()
}

fn main() {
    let bench = Bench::from_env();
    let mut report = Report::new();
    let mut rng = Rng::new(2024);

    // --- tidset intersection: sorted-vec vs bitmap, two densities ---
    for &density in &[0.05, 0.4] {
        let universe = 100_000;
        let a = random_tidset(&mut rng, universe, density);
        let b = random_tidset(&mut rng, universe, density);
        let ba = TidBitmap::from_tids(universe, a.iter().copied());
        let bb = TidBitmap::from_tids(universe, b.iter().copied());

        report.add(bench.run(format!("intersect/vec/d={density}"), || {
            black_box(intersect(&a, &b).len())
        }));
        report.add(bench.run(format!("intersect/vec_count/d={density}"), || {
            black_box(intersect_count(&a, &b))
        }));
        report.add(bench.run(format!("intersect/bitmap_count/d={density}"), || {
            black_box(ba.and_count(&bb))
        }));
        report.add(bench.run(format!("intersect/bitmap_and/d={density}"), || {
            black_box(ba.and(&bb).count())
        }));
    }

    // --- skewed (galloping) intersection ---
    {
        let small = random_tidset(&mut rng, 100_000, 0.001);
        let large = random_tidset(&mut rng, 100_000, 0.5);
        report.add(bench.run("intersect/vec_galloping", || {
            black_box(intersect(&small, &large).len())
        }));
    }

    // --- triangular matrix updates over transactions ---
    {
        let txns: Vec<Vec<u32>> = (0..5000)
            .map(|_| {
                let mut t: Vec<u32> = (0..20).map(|_| rng.below(200) as u32).collect();
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        report.add(bench.run("trimatrix/update_5k_txns_w20", || {
            let mut m = TriMatrix::new(199);
            for t in &txns {
                m.update_transaction(t);
            }
            black_box(m.support(1, 2))
        }));
    }

    // --- bottom-up recursion over a mid-sized class ---
    {
        let universe = 20_000;
        let members: Vec<(u32, Tidset)> = (0..24)
            .map(|i| (i, random_tidset(&mut rng, universe, 0.12)))
            .collect();
        let bitmap_members: Vec<(u32, TidBitmap)> = members
            .iter()
            .map(|(i, t)| (*i, TidBitmap::from_tids(universe, t.iter().copied())))
            .collect();
        let min_sup = (universe as f64 * 0.012) as u32;
        report.add(bench.run("bottomup/tidset_24atoms", || {
            let mut out = Vec::new();
            bottom_up::<Tidset>(&[0], &members, min_sup, &mut out);
            black_box(out.len())
        }));
        report.add(bench.run("bottomup/bitmap_24atoms", || {
            let mut out = Vec::new();
            bottom_up::<TidBitmap>(&[0], &bitmap_members, min_sup, &mut out);
            black_box(out.len())
        }));
    }

    // --- Apriori candidate subset counting ---
    {
        let mut trie = CandidateTrie::new();
        for i in 0..40u32 {
            for j in (i + 1)..40 {
                trie.insert(&[i, j]);
            }
        }
        let txns: Vec<Vec<u32>> = (0..2000)
            .map(|_| {
                let mut t: Vec<u32> = (0..15).map(|_| rng.below(40) as u32).collect();
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        report.add(bench.run("apriori/count_780cands_2k_txns", || {
            let mut counts = vec![0u32; trie.len()];
            for t in &txns {
                trie.count_subsets(t, &mut counts);
            }
            black_box(counts[0])
        }));
    }

    report.write_csv("bench_fim_micro.csv").expect("write csv");
    println!("\nwrote results/bench_fim_micro.csv");

    // Perf trajectory: BENCH_fim.json at the repo root (cargo runs
    // benches with the package dir as CWD, hence the `..`).
    let out = std::env::var("BENCH_FIM_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_fim.json", env!("CARGO_MANIFEST_DIR"))
    });
    let scale = std::env::var("SCALE").unwrap_or_else(|_| "paper".to_string());
    report.write_json(&out, "fim_micro", &scale).expect("write BENCH_fim.json");
    println!("wrote {out}");
}
