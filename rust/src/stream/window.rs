//! Tumbling and sliding micro-batch windows with tid-range bookkeeping.
//!
//! The window is the unit of scoping for streaming FIM: every emitted
//! result covers the transactions of the last `batches` micro-batches,
//! re-evaluated every `slide` batches (Spark Streaming's
//! `window(length, slideInterval)`, measured in batches instead of
//! wall time). The window owns global transaction-id assignment — each
//! ingested batch occupies a contiguous, monotonically increasing tid
//! range, which is what lets the incremental vertical store evict whole
//! batches with one bitmap range-mask per touched item.

use std::collections::VecDeque;

use crate::fim::{Database, Item, Tid};

/// Window geometry, in batches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowSpec {
    /// Window length: how many of the most recent batches are in scope.
    pub batches: usize,
    /// Emission cadence: mine after every `slide` ingested batches.
    /// `slide == batches` is a tumbling window; `slide < batches` a
    /// sliding one; `slide > batches` leaves gaps (legal — batches pass
    /// through the window between emissions).
    pub slide: usize,
}

impl WindowSpec {
    /// Non-overlapping windows: every transaction is mined exactly once.
    pub fn tumbling(batches: usize) -> WindowSpec {
        WindowSpec::sliding(batches, batches)
    }

    /// Overlapping windows re-evaluated every `slide` batches.
    pub fn sliding(batches: usize, slide: usize) -> WindowSpec {
        assert!(batches >= 1, "window must span at least one batch");
        assert!(slide >= 1, "slide must be at least one batch");
        WindowSpec { batches, slide }
    }

    /// True when windows do not overlap.
    pub fn is_tumbling(&self) -> bool {
        self.slide == self.batches
    }
}

/// One ingested micro-batch held live by the window.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Batch {
    /// Sequence number (0-based ingestion order).
    pub id: u64,
    /// First global tid of this batch.
    pub tid_lo: Tid,
    /// Transaction count — authoritative even without retained rows.
    pub txns: usize,
    /// Distinct items occurring in the batch, sorted ascending — the
    /// eviction hint: it lets the vertical store clear only the touched
    /// bitmaps (O(items in batch), not O(all live items)) while the
    /// window stays row-free. Orders of magnitude smaller than `rows`.
    pub items: Vec<Item>,
    /// Transactions, each sorted and de-duplicated. **Empty when the
    /// window runs row-free** ([`SlidingWindow::row_free`]): the
    /// streaming job's incremental mode keeps every live transaction in
    /// the vertical store already, so retaining them here horizontally
    /// would double window memory — evictions are handled by tid range
    /// plus the `items` hint, and window contents are reconstructed from
    /// the store on demand.
    pub rows: Vec<Vec<Item>>,
}

impl Batch {
    /// One past the last global tid of this batch.
    pub fn tid_hi(&self) -> Tid {
        self.tid_lo + self.txns as Tid
    }
}

/// Outcome of ingesting one batch.
#[derive(Debug)]
pub struct PushResult {
    /// Sequence number assigned to the ingested batch.
    pub batch_id: u64,
    /// First global tid assigned to the ingested batch.
    pub tid_lo: Tid,
    /// Batches that fell out of the window, oldest first.
    pub evicted: Vec<Batch>,
    /// True when a window emission is due (every `slide` pushes).
    pub emit: bool,
}

/// A sliding window over micro-batches.
#[derive(Debug)]
pub struct SlidingWindow {
    spec: WindowSpec,
    live: VecDeque<Batch>,
    next_tid: Tid,
    next_id: u64,
    pushes_since_emit: usize,
    txns: usize,
    /// When false, ingested rows are dropped after counting — only batch
    /// geometry (id, tid range, size) is tracked. See
    /// [`SlidingWindow::row_free`].
    retain_rows: bool,
}

/// Canonicalize one transaction the way [`Database::from_rows`] does.
pub fn normalize_row(mut row: Vec<Item>) -> Vec<Item> {
    row.sort_unstable();
    row.dedup();
    row
}

impl SlidingWindow {
    /// Empty window with the given geometry, retaining row contents (the
    /// from-scratch mining path needs [`SlidingWindow::materialize`]).
    pub fn new(spec: WindowSpec) -> SlidingWindow {
        SlidingWindow::build(spec, true)
    }

    /// Empty window that tracks only batch geometry — no row contents.
    /// For drivers that already hold every live transaction elsewhere
    /// (the incremental vertical store), so window memory is not paid
    /// twice. [`SlidingWindow::materialize`] is unavailable in this mode;
    /// evicted [`Batch`]es carry their size and tid range only.
    pub fn row_free(spec: WindowSpec) -> SlidingWindow {
        SlidingWindow::build(spec, false)
    }

    fn build(spec: WindowSpec, retain_rows: bool) -> SlidingWindow {
        SlidingWindow {
            spec,
            live: VecDeque::with_capacity(spec.batches + 1),
            next_tid: 0,
            next_id: 0,
            pushes_since_emit: 0,
            txns: 0,
            retain_rows,
        }
    }

    /// The geometry.
    pub fn spec(&self) -> WindowSpec {
        self.spec
    }

    /// True when row contents are retained (see [`SlidingWindow::row_free`]).
    pub fn retains_rows(&self) -> bool {
        self.retain_rows
    }

    /// Ingest one batch (rows must already be normalized — see
    /// [`normalize_row`]). Assigns its tid range, evicts batches that
    /// fall out of scope, and reports whether an emission is due.
    pub fn push(&mut self, rows: Vec<Vec<Item>>) -> PushResult {
        debug_assert!(
            rows.iter().all(|r| r.windows(2).all(|w| w[0] < w[1])),
            "rows must be sorted and de-duplicated"
        );
        let txns = rows.len();
        let mut items: Vec<Item> = rows.iter().flatten().copied().collect();
        items.sort_unstable();
        items.dedup();
        let batch = Batch {
            id: self.next_id,
            tid_lo: self.next_tid,
            txns,
            items,
            rows: if self.retain_rows { rows } else { Vec::new() },
        };
        self.next_id += 1;
        self.next_tid = batch.tid_hi();
        self.txns += txns;
        let (batch_id, tid_lo) = (batch.id, batch.tid_lo);
        self.live.push_back(batch);
        let mut evicted = Vec::new();
        while self.live.len() > self.spec.batches {
            let old = self.live.pop_front().expect("live is non-empty");
            self.txns -= old.txns;
            evicted.push(old);
        }
        self.pushes_since_emit += 1;
        let emit = self.pushes_since_emit >= self.spec.slide;
        if emit {
            self.pushes_since_emit = 0;
        }
        PushResult { batch_id, tid_lo, evicted, emit }
    }

    /// Live transaction count.
    pub fn txns(&self) -> usize {
        self.txns
    }

    /// Number of live batches (≤ `spec.batches`).
    pub fn len_batches(&self) -> usize {
        self.live.len()
    }

    /// Global tid range `[lo, hi)` currently live. `lo == hi` when empty.
    pub fn tid_range(&self) -> (Tid, Tid) {
        match self.live.front() {
            Some(b) => (b.tid_lo, self.next_tid),
            None => (self.next_tid, self.next_tid),
        }
    }

    /// Preview the evictions the **next** [`SlidingWindow::push`] will
    /// perform, oldest first, as `(txns, distinct-item hint)` pairs. The
    /// incoming batch always survives its own push (`spec.batches >= 1`),
    /// so the preview depends only on current state: the
    /// `(live.len() + 1) - spec.batches` oldest live batches fall out.
    /// Lets a caller that bookkeeps eviction *before* handing rows to
    /// `push` (the sharded store fuses append + evict into one parallel
    /// pass per shard) know the evictions without consuming the result.
    pub fn pending_evictions(&self) -> Vec<(usize, Vec<Item>)> {
        let n = (self.live.len() + 1).saturating_sub(self.spec.batches);
        self.live.iter().take(n).map(|b| (b.txns, b.items.clone())).collect()
    }

    /// Materialize the live window as a horizontal [`Database`] (oldest
    /// transaction first) — the from-scratch mining path and the oracle
    /// the parity tests compare against. Requires a row-retaining window;
    /// row-free drivers reconstruct from their vertical store instead
    /// (`IncrementalVerticalDb::live_rows`).
    pub fn materialize(&self) -> Database {
        assert!(self.retain_rows, "materialize() needs a row-retaining window");
        let mut rows = Vec::with_capacity(self.txns);
        for b in &self.live {
            rows.extend(b.rows.iter().cloned());
        }
        Database::from_rows(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, tag: u32) -> Vec<Vec<Item>> {
        (0..n).map(|i| vec![tag, tag + 1 + i as u32]).collect()
    }

    #[test]
    #[should_panic(expected = "at least one batch")]
    fn zero_length_window_rejected() {
        WindowSpec::sliding(0, 1);
    }

    #[test]
    fn tumbling_emits_every_window_length() {
        let mut w = SlidingWindow::new(WindowSpec::tumbling(3));
        assert!(WindowSpec::tumbling(3).is_tumbling());
        let emits: Vec<bool> = (0..7).map(|i| w.push(rows(2, i)).emit).collect();
        assert_eq!(emits, vec![false, false, true, false, false, true, false]);
        assert_eq!(w.len_batches(), 3);
        assert_eq!(w.txns(), 6);
    }

    #[test]
    fn sliding_evicts_oldest_and_tracks_tids() {
        let mut w = SlidingWindow::new(WindowSpec::sliding(2, 1));
        let r0 = w.push(rows(3, 0));
        assert_eq!((r0.batch_id, r0.tid_lo), (0, 0));
        assert!(r0.emit && r0.evicted.is_empty());
        let r1 = w.push(rows(2, 10));
        assert_eq!(r1.tid_lo, 3);
        assert!(r1.evicted.is_empty());
        let r2 = w.push(rows(4, 20));
        assert_eq!(r2.tid_lo, 5);
        assert_eq!(r2.evicted.len(), 1);
        assert_eq!(r2.evicted[0].id, 0);
        assert_eq!((r2.evicted[0].tid_lo, r2.evicted[0].tid_hi()), (0, 3));
        assert_eq!(w.tid_range(), (3, 9));
        assert_eq!(w.txns(), 6);
    }

    #[test]
    fn slide_larger_than_window_passes_batches_through() {
        // Window of 1 batch, emission every 3: batches are evicted without
        // ever being mined — the "gap" geometry.
        let mut w = SlidingWindow::new(WindowSpec::sliding(1, 3));
        assert!(!w.push(rows(1, 0)).emit);
        let r = w.push(rows(1, 10));
        assert!(!r.emit);
        assert_eq!(r.evicted.len(), 1);
        let r = w.push(rows(1, 20));
        assert!(r.emit);
        assert_eq!(w.txns(), 1);
        assert_eq!(w.materialize().transactions()[0], vec![20, 21]);
    }

    #[test]
    fn materialize_concatenates_live_batches_in_order() {
        let mut w = SlidingWindow::new(WindowSpec::sliding(2, 2));
        w.push(vec![vec![1, 2], vec![]]);
        w.push(vec![vec![3]]);
        let db = w.materialize();
        assert_eq!(db.len(), 3);
        assert_eq!(db.transactions()[0], vec![1, 2]);
        assert!(db.transactions()[1].is_empty(), "empty transactions are kept");
        assert_eq!(db.transactions()[2], vec![3]);
    }

    #[test]
    fn row_free_window_tracks_geometry_without_rows() {
        let mut w = SlidingWindow::row_free(WindowSpec::sliding(2, 1));
        assert!(!w.retains_rows());
        w.push(rows(3, 0));
        w.push(rows(2, 10));
        let r = w.push(rows(4, 20));
        // Same geometry as the retaining window…
        assert_eq!(w.txns(), 6);
        assert_eq!(w.tid_range(), (3, 9));
        assert_eq!(r.evicted.len(), 1);
        assert_eq!(r.evicted[0].txns, 3);
        assert_eq!((r.evicted[0].tid_lo, r.evicted[0].tid_hi()), (0, 3));
        // …but no row contents anywhere — only the distinct-item hint.
        assert!(r.evicted[0].rows.is_empty());
        assert_eq!(r.evicted[0].items, vec![0, 1, 2, 3], "sorted distinct items");
    }

    #[test]
    #[should_panic(expected = "row-retaining")]
    fn row_free_window_rejects_materialize() {
        let mut w = SlidingWindow::row_free(WindowSpec::tumbling(1));
        w.push(rows(1, 0));
        let _ = w.materialize();
    }

    #[test]
    fn empty_batches_are_legal() {
        let mut w = SlidingWindow::new(WindowSpec::sliding(2, 1));
        let r = w.push(Vec::new());
        assert!(r.emit);
        assert_eq!(w.txns(), 0);
        assert_eq!(w.tid_range(), (0, 0));
        w.push(rows(2, 5));
        assert_eq!(w.tid_range(), (0, 2));
    }

    #[test]
    fn pending_evictions_previews_the_next_push() {
        let mut w = SlidingWindow::row_free(WindowSpec::sliding(2, 1));
        assert!(w.pending_evictions().is_empty(), "empty window evicts nothing");
        w.push(rows(3, 0));
        assert!(w.pending_evictions().is_empty(), "window not yet full");
        w.push(rows(2, 10));
        // Window is full: the next push must evict exactly batch 0.
        let preview = w.pending_evictions();
        assert_eq!(preview, vec![(3, vec![0, 1, 2, 3])]);
        let r = w.push(rows(4, 20));
        assert_eq!(r.evicted.len(), preview.len());
        assert_eq!((r.evicted[0].txns, r.evicted[0].items.clone()), preview[0]);
        // Gap geometry (window 1, any slide): every push past the first
        // evicts the sole live batch.
        let mut g = SlidingWindow::row_free(WindowSpec::sliding(1, 3));
        g.push(rows(2, 0));
        assert_eq!(g.pending_evictions(), vec![(2, vec![0, 1, 2])]);
    }

    #[test]
    fn normalize_row_sorts_and_dedups() {
        assert_eq!(normalize_row(vec![5, 1, 5, 3]), vec![1, 3, 5]);
        assert!(normalize_row(vec![]).is_empty());
    }
}
