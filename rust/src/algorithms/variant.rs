//! The unified miner façade: the [`Variant`] registry and the
//! [`MiningSession`] builder.
//!
//! The paper's contribution is a *family* of interchangeable algorithms
//! (five RDD-Eclat variants against Apriori/FP-Growth baselines), so the
//! public API treats algorithm choice as data: [`Variant`] is the single
//! registry mapping names to constructors (replacing the string matches
//! that used to live in `bin/repro.rs`, `figures/`, and the benches),
//! and [`MiningSession`] owns the cross-variant run concerns — input
//! wiring, options validation, and the single [`FimResult`] assembly
//! path (see [`super::FimResultBuilder`]).
//!
//! ```
//! use rdd_eclat::prelude::*;
//!
//! let db = Database::from_rows(vec![vec![1, 2], vec![1, 2, 3], vec![2, 3]]);
//! let ctx = ClusterContext::builder().cores(2).build();
//! let result = MiningSession::on(&ctx)
//!     .db(&db)
//!     .min_sup(MinSup::count(2))
//!     .run(Variant::V5)
//!     .unwrap();
//! assert!(result.contains(&[1, 2], 2));
//! ```

use std::str::FromStr;

use crate::engine::ClusterContext;
use crate::error::{Error, Result};
use crate::fim::{Database, MinSup};

use super::{
    Algorithm, CoocStrategy, EclatOptions, EclatV1, EclatV2, EclatV3, EclatV4, EclatV5,
    FimResult, RddApriori, SeqApriori, SeqEclat, SeqEclatDiffset, SeqFpGrowth,
};

/// Every algorithm the crate can run, as a value. The registry behind
/// CLI dispatch (`--algo`, via [`FromStr`]), the figure drivers, and the
/// benches; [`Variant::build`] is the only place a concrete algorithm
/// type is named.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// EclatV1: `groupByKey` vertical DB, default `(n−1)` partitioner.
    V1,
    /// EclatV2: V1 + Borgelt transaction filtering.
    V2,
    /// EclatV3: V2 with the vertical DB accumulated, not shuffled.
    V3,
    /// EclatV4: V3 + hash partitioner `v % p`.
    V4,
    /// EclatV5: V3 + reverse-hash partitioner.
    V5,
    /// The YAFIM-style RDD-Apriori baseline.
    Apriori,
    /// Sequential Eclat (tidsets; the correctness oracle).
    Seq,
    /// Sequential dEclat (diffsets).
    SeqDiffset,
    /// Sequential Apriori (Agrawal–Srikant).
    SeqApriori,
    /// Sequential FP-Growth (Han et al.).
    FpGrowth,
}

impl Variant {
    /// Every registered variant, distributed first.
    pub const ALL: [Variant; 10] = [
        Variant::V1,
        Variant::V2,
        Variant::V3,
        Variant::V4,
        Variant::V5,
        Variant::Apriori,
        Variant::Seq,
        Variant::SeqDiffset,
        Variant::SeqApriori,
        Variant::FpGrowth,
    ];

    /// The six algorithms of the paper's Figs 8–14 comparison panels.
    pub const STANDARD: [Variant; 6] = [
        Variant::V1,
        Variant::V2,
        Variant::V3,
        Variant::V4,
        Variant::V5,
        Variant::Apriori,
    ];

    /// The five RDD-Eclat variants (the paper's contribution).
    pub const RDD_ECLAT: [Variant; 5] =
        [Variant::V1, Variant::V2, Variant::V3, Variant::V4, Variant::V5];

    /// Every registered variant, as a slice.
    pub fn all() -> &'static [Variant] {
        &Self::ALL
    }

    /// Canonical name — matches what [`Algorithm::name`] reports for the
    /// built algorithm.
    pub fn name(self) -> &'static str {
        match self {
            Variant::V1 => "eclatV1",
            Variant::V2 => "eclatV2",
            Variant::V3 => "eclatV3",
            Variant::V4 => "eclatV4",
            Variant::V5 => "eclatV5",
            Variant::Apriori => "apriori",
            Variant::Seq => "seq-eclat",
            Variant::SeqDiffset => "seq-declat",
            Variant::SeqApriori => "seq-apriori",
            Variant::FpGrowth => "seq-fpgrowth",
        }
    }

    /// One-line description for `--list-algos` style listings.
    pub fn describe(self) -> &'static str {
        match self {
            Variant::V1 => "vertical DB via groupByKey, default (n-1) class partitioner (§4.1)",
            Variant::V2 => "V1 + Borgelt transaction filtering (§4.2)",
            Variant::V3 => "V2 with the vertical DB accumulated instead of shuffled (§4.3)",
            Variant::V4 => "V3 + hash class partitioner v % p (§4.4)",
            Variant::V5 => "V3 + reverse-hash class partitioner (§4.4)",
            Variant::Apriori => "YAFIM-style RDD-Apriori baseline (broadcast candidate trie)",
            Variant::Seq => "sequential Eclat oracle (tidsets, arena miner)",
            Variant::SeqDiffset => "sequential dEclat (diffsets)",
            Variant::SeqApriori => "sequential Apriori (Agrawal-Srikant)",
            Variant::FpGrowth => "sequential FP-Growth (Han et al.)",
        }
    }

    /// Construct the algorithm. `options` applies to the RDD-Eclat
    /// variants; the baselines and sequential oracles take no options
    /// and ignore it.
    pub fn build(self, options: &EclatOptions) -> Box<dyn Algorithm> {
        match self {
            Variant::V1 => Box::new(EclatV1::with_options(options.clone())),
            Variant::V2 => Box::new(EclatV2::with_options(options.clone())),
            Variant::V3 => Box::new(EclatV3::with_options(options.clone())),
            Variant::V4 => Box::new(EclatV4::with_options(options.clone())),
            Variant::V5 => Box::new(EclatV5::with_options(options.clone())),
            Variant::Apriori => Box::new(RddApriori),
            Variant::Seq => Box::new(SeqEclat),
            Variant::SeqDiffset => Box::new(SeqEclatDiffset),
            Variant::SeqApriori => Box::new(SeqApriori),
            Variant::FpGrowth => Box::new(SeqFpGrowth),
        }
    }

    /// The `valid names: …` suffix used in parse errors and usage text.
    fn valid_names() -> String {
        Variant::ALL.iter().map(|v| v.name()).collect::<Vec<_>>().join(", ")
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Variant {
    type Err = Error;

    /// Case-insensitive; accepts the canonical names plus the historical
    /// CLI aliases (`v4`, `yafim`, `fpgrowth`, …). Unknown names error
    /// with the full list of valid names.
    fn from_str(s: &str) -> Result<Variant> {
        match s.to_ascii_lowercase().as_str() {
            "eclatv1" | "v1" => Ok(Variant::V1),
            "eclatv2" | "v2" => Ok(Variant::V2),
            "eclatv3" | "v3" => Ok(Variant::V3),
            "eclatv4" | "v4" => Ok(Variant::V4),
            "eclatv5" | "v5" => Ok(Variant::V5),
            "apriori" | "rdd-apriori" | "yafim" => Ok(Variant::Apriori),
            "seq-eclat" | "seq" | "eclat" => Ok(Variant::Seq),
            "seq-declat" | "declat" | "diffset" => Ok(Variant::SeqDiffset),
            "seq-apriori" => Ok(Variant::SeqApriori),
            "seq-fpgrowth" | "fpgrowth" | "fp-growth" => Ok(Variant::FpGrowth),
            other => Err(Error::Usage(format!(
                "unknown algorithm {other:?}; valid names: {}",
                Variant::valid_names()
            ))),
        }
    }
}

/// Builder for one mining run: wires a database and support threshold to
/// a cluster context, validates the shared [`EclatOptions`] once, and
/// dispatches any [`Variant`] (or a custom [`Algorithm`]) through the
/// single result-assembly path.
///
/// A session borrows its inputs and can run several variants back to
/// back — the pattern the figure drivers use for the paper's comparison
/// panels:
///
/// ```
/// use rdd_eclat::prelude::*;
///
/// let db = Database::from_rows(vec![vec![1, 2], vec![1, 2, 3], vec![2, 3]]);
/// let ctx = ClusterContext::builder().cores(2).build();
/// let session = MiningSession::on(&ctx).db(&db).min_sup(MinSup::count(2)).partitions(4);
/// let v4 = session.run(Variant::V4).unwrap();
/// let v5 = session.run(Variant::V5).unwrap();
/// assert_eq!(v4.len(), v5.len());
/// ```
#[derive(Debug, Clone)]
pub struct MiningSession<'a> {
    ctx: &'a ClusterContext,
    db: Option<&'a Database>,
    min_sup: Option<MinSup>,
    options: EclatOptions,
}

impl<'a> MiningSession<'a> {
    /// Start a session on a cluster context.
    pub fn on(ctx: &'a ClusterContext) -> MiningSession<'a> {
        MiningSession { ctx, db: None, min_sup: None, options: EclatOptions::default() }
    }

    /// The database to mine (required).
    pub fn db(mut self, db: &'a Database) -> Self {
        self.db = Some(db);
        self
    }

    /// The support threshold (required).
    pub fn min_sup(mut self, min_sup: MinSup) -> Self {
        self.min_sup = Some(min_sup);
        self
    }

    /// Replace the full option set.
    pub fn options(mut self, options: EclatOptions) -> Self {
        self.options = options;
        self
    }

    /// Toggle the triangular-matrix optimization (`triMatrixMode`).
    pub fn tri_matrix(mut self, on: bool) -> Self {
        self.options.tri_matrix = on;
        self
    }

    /// Equivalence-class partition count `p` (V4/V5).
    pub fn partitions(mut self, p: usize) -> Self {
        self.options.partitions = p;
        self
    }

    /// Phase-2 co-occurrence strategy (accumulator vs provider).
    pub fn cooc(mut self, strategy: CoocStrategy) -> Self {
        self.options.cooc = strategy;
        self
    }

    /// The session's current options (what [`MiningSession::run`] will
    /// hand to [`Variant::build`]).
    pub fn current_options(&self) -> &EclatOptions {
        &self.options
    }

    /// Validate and run one variant. Options are validated *before* the
    /// algorithm is constructed (the [`EclatOptions::validate`]
    /// contract), so no variant is ever built from bad options.
    pub fn run(&self, variant: Variant) -> Result<FimResult> {
        self.options.validate()?;
        let (db, min_sup) = self.inputs()?;
        variant.build(&self.options).run_on(self.ctx, db, min_sup)
    }

    /// Validate and run a custom [`Algorithm`] (the extension point for
    /// algorithms outside the registry).
    pub fn run_algorithm(&self, algo: &dyn Algorithm) -> Result<FimResult> {
        self.options.validate()?;
        let (db, min_sup) = self.inputs()?;
        algo.run_on(self.ctx, db, min_sup)
    }

    /// The required inputs, or a config error naming the missing call.
    fn inputs(&self) -> Result<(&'a Database, MinSup)> {
        let db = self.db.ok_or_else(|| {
            Error::Config("MiningSession: no database — call .db(&db) before .run(..)".into())
        })?;
        let min_sup = self.min_sup.ok_or_else(|| {
            Error::Config(
                "MiningSession: no support threshold — call .min_sup(..) before .run(..)".into(),
            )
        })?;
        Ok((db, min_sup))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn names_round_trip_through_fromstr_and_display() {
        for &v in Variant::all() {
            assert_eq!(v.name().parse::<Variant>().unwrap(), v);
            assert_eq!(v.to_string(), v.name());
            assert_eq!(v.build(&EclatOptions::default()).name(), v.name());
            assert!(!v.describe().is_empty());
        }
    }

    #[test]
    fn historical_aliases_still_parse() {
        for (alias, want) in [
            ("eclatV1", Variant::V1),
            ("v2", Variant::V2),
            ("EclatV3", Variant::V3),
            ("V4", Variant::V4),
            ("eclatv5", Variant::V5),
            ("yafim", Variant::Apriori),
            ("rdd-apriori", Variant::Apriori),
            ("seq", Variant::Seq),
            ("declat", Variant::SeqDiffset),
            ("fpgrowth", Variant::FpGrowth),
            ("seq-apriori", Variant::SeqApriori),
        ] {
            assert_eq!(alias.parse::<Variant>().unwrap(), want, "{alias}");
        }
    }

    #[test]
    fn unknown_name_error_enumerates_valid_names() {
        let err = "telepathy".parse::<Variant>().unwrap_err().to_string();
        assert!(err.contains("telepathy"), "{err}");
        for &v in Variant::all() {
            assert!(err.contains(v.name()), "{} missing from: {err}", v.name());
        }
    }

    #[test]
    fn session_requires_db_and_min_sup_and_valid_options() {
        let ctx = ClusterContext::builder().cores(1).build();
        let db = demo_db();
        let no_db = MiningSession::on(&ctx).min_sup(MinSup::count(2));
        assert!(no_db.run(Variant::Seq).unwrap_err().to_string().contains("no database"));
        let no_sup = MiningSession::on(&ctx).db(&db);
        assert!(no_sup.run(Variant::Seq).unwrap_err().to_string().contains("no support"));
        let bad_opts = MiningSession::on(&ctx).db(&db).min_sup(MinSup::count(2)).partitions(0);
        assert!(bad_opts.run(Variant::V4).unwrap_err().to_string().contains("partitions"));
    }

    #[test]
    fn session_threads_options_through_to_the_variant() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        let r = MiningSession::on(&ctx)
            .db(&db)
            .min_sup(MinSup::count(2))
            .partitions(3)
            .run(Variant::V4)
            .unwrap();
        assert_eq!(r.partition_loads.len(), 3, "p reached the partitioner");
        assert_eq!(r.algorithm, "eclatV4");
    }
}
