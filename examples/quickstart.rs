//! Quickstart: mine a tiny in-memory market-basket database with
//! RDD-Eclat and print the frequent itemsets and a couple of rules.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use rdd_eclat::algorithms::{MiningSession, Variant};
use rdd_eclat::data::Database;
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{generate_rules, sort_frequents, MinSup};

fn main() -> rdd_eclat::error::Result<()> {
    // Items: 1=bread 2=milk 3=butter 4=beer 5=diapers.
    let db = Database::from_rows(vec![
        vec![1, 2, 3],
        vec![1, 2],
        vec![2, 3],
        vec![1, 2, 3],
        vec![4, 5],
        vec![1, 4, 5],
        vec![1, 2, 5],
        vec![2, 3, 5],
    ]);

    // A local "cluster" with two executor cores.
    let ctx = ClusterContext::builder().cores(2).build();

    // EclatV4: the paper's best-performing variant (hash-partitioned
    // equivalence classes), dispatched through the miner façade.
    let result = MiningSession::on(&ctx)
        .db(&db)
        .min_sup(MinSup::count(3))
        .run(Variant::V4)?;

    let mut frequents = result.frequents.clone();
    sort_frequents(&mut frequents);
    println!("frequent itemsets (support >= 3):");
    for f in &frequents {
        println!("  {f}");
    }

    println!("\nassociation rules (confidence >= 0.7):");
    for rule in generate_rules(&frequents, 0.7, Some(db.len())) {
        println!("  {rule}");
    }

    println!("\nmined in {:?} across phases:", result.wall);
    for p in &result.phases {
        println!("  {:<8} {:?}", p.name, p.wall);
    }
    Ok(())
}
