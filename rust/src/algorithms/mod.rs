//! The paper's algorithms: five RDD-Eclat variants (EclatV1–V5), the
//! YAFIM-style RDD-Apriori baseline, and sequential oracles — all running
//! on the [`crate::engine`] RDD substrate.
//!
//! | Variant | Phase structure (paper §4) |
//! |---|---|
//! | `EclatV1` | vertical DB via `groupByKey` on the raw transactions; triangular matrix accumulator; equivalence classes on the default `(n−1)` partitioner |
//! | `EclatV2` | + Borgelt transaction filtering (word-count Phase-1, broadcast item trie) |
//! | `EclatV3` | vertical DB accumulated in a shared hashmap accumulator instead of a shuffle |
//! | `EclatV4` | EclatV3 + hash partitioner `v % p` |
//! | `EclatV5` | EclatV3 + reverse-hash partitioner |
//! | `RddApriori` | YAFIM: per-level candidate broadcast + subset-count `reduceByKey` |

pub mod apriori_rdd;
pub mod common;
pub mod eclat_v1;
pub mod eclat_v2;
pub mod eclat_v3;
pub mod eclat_v45;
pub mod partitioners;
pub mod seq;

use std::sync::Arc;
use std::time::Duration;

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{Database, Frequent, Item, MinSup, TriMatrix};

pub use apriori_rdd::RddApriori;
pub use eclat_v1::EclatV1;
pub use eclat_v2::EclatV2;
pub use eclat_v3::EclatV3;
pub use eclat_v45::{EclatV4, EclatV5};
pub use seq::{SeqApriori, SeqEclat, SeqEclatDiffset, SeqFpGrowth};

/// One timed phase of an algorithm run.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Phase name as in the paper ("phase1", "phase2", ...).
    pub name: String,
    /// Wall time of the phase.
    pub wall: Duration,
}

/// The output of one mining run: the frequent itemsets plus run metadata
/// used by the experiment harness.
#[derive(Debug, Clone)]
pub struct FimResult {
    /// Which algorithm produced this.
    pub algorithm: String,
    /// All frequent itemsets with supports (unsorted; use
    /// [`crate::fim::sort_frequents`] for canonical order).
    pub frequents: Vec<Frequent>,
    /// Total wall time of the run.
    pub wall: Duration,
    /// Per-phase breakdown.
    pub phases: Vec<Phase>,
    /// Equivalence-class members routed to each partition (the §4.5
    /// workload measure; empty for non-Eclat algorithms).
    pub partition_loads: Vec<usize>,
    /// Fractional reduction of total item occurrences achieved by
    /// transaction filtering (EclatV2+; `None` when not applicable).
    pub filtered_reduction: Option<f64>,
}

impl FimResult {
    /// Does the result contain `items` with exactly `support`?
    pub fn contains(&self, items: &[Item], support: u32) -> bool {
        self.frequents.iter().any(|f| f.items == items && f.support == support)
    }

    /// Number of frequent itemsets found.
    pub fn len(&self) -> usize {
        self.frequents.len()
    }

    /// True when nothing is frequent.
    pub fn is_empty(&self) -> bool {
        self.frequents.is_empty()
    }
}

/// A frequent-itemset mining algorithm runnable on a cluster context.
pub trait Algorithm: Send + Sync {
    /// Short name for tables/CSV ("eclatV1", "apriori", ...).
    fn name(&self) -> &'static str;

    /// Mine `db` at `min_sup` on `ctx`.
    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult>;
}

/// Strategy for computing the Phase-2 triangular matrix.
#[derive(Clone)]
pub enum CoocStrategy {
    /// The paper's approach: per-partition local matrices merged through a
    /// Spark accumulator.
    Accumulator,
    /// A pluggable provider (the XLA/PJRT AOT-kernel backend lives here;
    /// see `runtime::cooc`), called per partition batch.
    Provider(Arc<dyn TriMatrixProvider>),
}

impl std::fmt::Debug for CoocStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoocStrategy::Accumulator => write!(f, "Accumulator"),
            CoocStrategy::Provider(_) => write!(f, "Provider(..)"),
        }
    }
}

/// Computes the candidate-2-itemset co-occurrence matrix for a batch of
/// transactions. Implemented natively (loops) and by the PJRT runtime
/// (AOT `cooc` kernel).
pub trait TriMatrixProvider: Send + Sync {
    /// Count all 2-itemset occurrences of `transactions` into a matrix
    /// covering items `0..=max_item`.
    fn compute(&self, transactions: &[Vec<Item>], max_item: Item) -> Result<TriMatrix>;
}

/// Shared knobs of the Eclat variants (the paper's `triMatrixMode` and
/// `p`).
#[derive(Debug, Clone)]
pub struct EclatOptions {
    /// Enable the triangular-matrix optimization (`triMatrixMode`).
    pub tri_matrix: bool,
    /// Number of equivalence-class partitions `p` (V4/V5 only; the paper
    /// uses 10).
    pub partitions: usize,
    /// How Phase-2 computes the matrix.
    pub cooc: CoocStrategy,
}

impl Default for EclatOptions {
    fn default() -> Self {
        EclatOptions { tri_matrix: true, partitions: 10, cooc: CoocStrategy::Accumulator }
    }
}
