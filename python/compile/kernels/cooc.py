"""L1 Pallas kernel: blocked item co-occurrence counting.

The paper's Phase-2 builds a triangular matrix of candidate-2-itemset
counts by looping over every 2-combination of every transaction. On TPU
that computation is a matmul: encode a block of transactions as a 0/1
matrix ``A`` of shape ``(T, I)`` (transaction x item); then

    C = A^T @ B     with  B = A  (or another item-column block)

gives ``C[i, j] = |{t : A[t,i]=1 and B[t,j]=1}|`` — exactly the
co-occurrence counts, computed by the MXU systolic array instead of a
scalar loop (DESIGN.md §3 Hardware-Adaptation).

The kernel tiles the transaction (reduction) dimension through VMEM with
``BlockSpec``s: each grid step loads a ``(BLOCK_T, I)`` tile pair and
accumulates into the resident ``(I, I)`` output tile. VMEM at the default
shape (256x128 f32 tiles): 2*128KiB in + 64KiB out, far under the ~16MiB
budget, leaving room for double buffering (DESIGN.md §8).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the lowered HLO is plain dots + adds, which the rust side
compiles and runs. On a real TPU the same kernel lowers to MXU ops.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT block shape (transactions x items per tile).
BLOCK_T = 64
DEFAULT_T = 256
DEFAULT_I = 128


def _cooc_kernel(a_ref, b_ref, o_ref):
    """One grid step: o += a_tile^T @ b_tile."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=jnp.float32
    )


@partial(jax.jit, static_argnames=("block_t",))
def cooc(a, b, *, block_t: int = BLOCK_T):
    """Co-occurrence counts ``a^T @ b`` for 0/1 f32 blocks.

    Args:
      a: ``(T, I_a)`` f32 0/1 transaction block (item columns ``I_a``).
      b: ``(T, I_b)`` f32 0/1 transaction block.
      block_t: reduction tile height; must divide ``T``.

    Returns:
      ``(I_a, I_b)`` f32 co-occurrence counts.
    """
    t, i_a = a.shape
    t_b, i_b = b.shape
    assert t == t_b, f"transaction dims differ: {t} vs {t_b}"
    assert t % block_t == 0, f"T={t} not divisible by block_t={block_t}"
    grid = (t // block_t,)
    return pl.pallas_call(
        _cooc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, i_a), lambda k: (k, 0)),
            pl.BlockSpec((block_t, i_b), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((i_a, i_b), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((i_a, i_b), jnp.float32),
        interpret=True,
    )(a, b)
