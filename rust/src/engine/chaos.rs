//! Seeded, deterministic fault injection for the execution engine.
//!
//! [`ChaosPolicy`] is the during-a-job counterpart to
//! [`crate::engine::lineage::FaultInjector`]: where the injector drops
//! cache blocks and shuffle outputs *between* jobs to exercise lineage
//! recovery, a chaos policy armed on a [`crate::engine::ClusterContext`]
//! perturbs tasks *while a job is running* — transient panics, straggler
//! delays and shuffle-fetch failures — so the scheduler's retry,
//! speculation and mid-job re-materialization paths are exercised under
//! test and benchmark.
//!
//! Every decision is a pure function of the policy seed and the stable
//! identity of the victim (`(job, stage, partition)` for tasks,
//! `(shuffle, reduce)` for fetches, the emission index for streaming), so
//! two runs with the same seed inject the *same* fault set regardless of
//! thread scheduling — which is what makes the recovery-equivalence
//! property ("a chaos run returns byte-identical results to a fault-free
//! run") testable at all.
//!
//! A policy can be armed three ways: explicitly per test via
//! [`crate::engine::ContextBuilder::chaos`], process-wide through the
//! `RDD_ECLAT_CHAOS=<seed>:<p>` environment variable (picked up by
//! [`crate::engine::ContextBuilder::build`] unless the builder says
//! otherwise), or from the CLI via `repro run --chaos <seed>:<p>`.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Mutex, PoisonError};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Environment variable that arms a default chaos policy process-wide
/// (format `<seed>:<p>`, e.g. `RDD_ECLAT_CHAOS=7:0.2`).
pub const CHAOS_ENV: &str = "RDD_ECLAT_CHAOS";

/// A fault the scheduler must apply to one task attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TaskFault {
    /// Fail this attempt as if the task body panicked.
    Panic,
    /// Delay this attempt by the given amount before running the body
    /// (a straggler; only ever injected on the first attempt so a
    /// speculative duplicate can win).
    Straggle(Duration),
}

/// A fault injected into one shard-RPC attempt (see
/// [`ChaosPolicy::net_fault`]). Applied driver-side by
/// [`crate::net::RemoteShardSet`]: a drop severs the connection before
/// the request is written (safe to resend), a corruption flips a byte
/// in the received reply so the frame CRC rejects it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum NetFault {
    /// Sever the worker connection before sending this attempt.
    DropConnection,
    /// Flip one byte of this attempt's reply frame.
    CorruptReply,
}

/// Seeded, deterministic mid-execution fault injector.
///
/// Probabilities select *victims* (which task, which fetch); the
/// injected failures themselves are bounded — a victim task fails only
/// its first `k` attempts (`k` is drawn below
/// [`ChaosPolicy::max_injected_failures`], which defaults to 2, safely
/// under the scheduler's default `max_task_failures` of 4), a victim
/// fetch fails only the first query of its `(shuffle, reduce)` pair, and
/// emission failures never exceed a consecutive cap. A chaos run is
/// therefore guaranteed to *recover*, which turns "results equal the
/// fault-free run" into a hard test assertion.
///
/// Attempt counters live behind a mutex inside the policy; cloning a
/// policy resets them (the clone re-injects the same fault set from
/// scratch).
pub struct ChaosPolicy {
    seed: u64,
    task_panic_p: f64,
    max_injected_failures: u32,
    straggler_p: f64,
    straggler_delay: Duration,
    shuffle_loss_p: f64,
    emission_p: f64,
    max_emission_failures: u32,
    conn_drop_p: f64,
    reply_corrupt_p: f64,
    /// Per-victim attempt counts: `(domain, a, b)` → attempts seen.
    /// Domain 0 = task `(job·stages + stage, partition)`, domain 1 =
    /// fetch `(shuffle, reduce)`, domain 2 = shard RPC `(worker, rpc)`.
    attempts: Mutex<HashMap<(u8, u64, u64), u32>>,
    /// `(next emission index, consecutive injected emission failures)`.
    emission_state: Mutex<(u64, u32)>,
}

impl ChaosPolicy {
    /// A policy with the given seed and *no* faults armed; chain the
    /// builder methods to switch individual fault classes on.
    pub fn new(seed: u64) -> ChaosPolicy {
        ChaosPolicy {
            seed,
            task_panic_p: 0.0,
            max_injected_failures: 2,
            straggler_p: 0.0,
            straggler_delay: Duration::from_millis(20),
            shuffle_loss_p: 0.0,
            emission_p: 0.0,
            max_emission_failures: 2,
            conn_drop_p: 0.0,
            reply_corrupt_p: 0.0,
            attempts: Mutex::new(HashMap::new()),
            emission_state: Mutex::new((0, 0)),
        }
    }

    /// The default suite armed by `--chaos <seed>:<p>` and the
    /// [`CHAOS_ENV`] variable: task panics at `p`, stragglers at `p/2`
    /// (20 ms delay), shuffle-fetch loss at `p/2`, shard-RPC connection
    /// drops and reply corruption at `p/2` each (only consulted when a
    /// remote shard set is attached). Emission failures stay off — they
    /// are opt-in via [`ChaosPolicy::emission_failures`] because only
    /// the async [`crate::stream::StreamService`] retries them.
    pub fn default_suite(seed: u64, p: f64) -> ChaosPolicy {
        ChaosPolicy::new(seed)
            .task_panics(p)
            .stragglers(p / 2.0, Duration::from_millis(20))
            .shuffle_loss(p / 2.0)
            .conn_drops(p / 2.0)
            .reply_corruption(p / 2.0)
    }

    /// Parse a `<seed>:<p>` spec (as taken by `--chaos` and
    /// [`CHAOS_ENV`]) into a [`ChaosPolicy::default_suite`].
    pub fn parse(spec: &str) -> Result<ChaosPolicy> {
        let bad = || Error::Config(format!("bad chaos spec {spec:?}: want <seed>:<p>, e.g. 7:0.2"));
        let (seed, p) = spec.split_once(':').ok_or_else(bad)?;
        let seed: u64 = seed.trim().parse().map_err(|_| bad())?;
        let p: f64 = p.trim().parse().map_err(|_| bad())?;
        if !(0.0..=1.0).contains(&p) {
            return Err(Error::Config(format!(
                "bad chaos spec {spec:?}: probability {p} outside [0, 1]"
            )));
        }
        Ok(ChaosPolicy::default_suite(seed, p))
    }

    /// Read [`CHAOS_ENV`] and arm a [`ChaosPolicy::default_suite`] from
    /// it; `None` when unset or empty. A malformed value is an error —
    /// silently mining without the faults CI asked for would defeat the
    /// point.
    pub fn from_env() -> Result<Option<ChaosPolicy>> {
        match std::env::var(CHAOS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => ChaosPolicy::parse(&spec).map(Some),
            _ => Ok(None),
        }
    }

    /// Make each task a victim of transient panics with probability `p`
    /// (the victim's first `k < max_injected_failures + 1` attempts
    /// fail, then it succeeds).
    pub fn task_panics(mut self, p: f64) -> ChaosPolicy {
        self.task_panic_p = p;
        self
    }

    /// Make each task a straggler with probability `p`, delaying its
    /// first attempt by `delay`.
    pub fn stragglers(mut self, p: f64, delay: Duration) -> ChaosPolicy {
        self.straggler_p = p;
        self.straggler_delay = delay;
        self
    }

    /// Fail (and drop the map outputs behind) the *first* fetch of each
    /// `(shuffle, reduce)` pair with probability `p` — the mid-job
    /// shuffle-loss scenario that forces the scheduler to re-run the map
    /// stage through lineage.
    pub fn shuffle_loss(mut self, p: f64) -> ChaosPolicy {
        self.shuffle_loss_p = p;
        self
    }

    /// Fail streaming emissions with probability `p`, never more than
    /// `max_consecutive` in a row (so a service whose death bound
    /// exceeds `max_consecutive` is guaranteed to keep serving).
    pub fn emission_failures(mut self, p: f64, max_consecutive: u32) -> ChaosPolicy {
        self.emission_p = p;
        self.max_emission_failures = max_consecutive;
        self
    }

    /// Sever each shard-RPC's worker connection with probability `p`,
    /// first attempt only — the resend is guaranteed clean, mirroring
    /// [`ChaosPolicy::shuffle_loss`], so a bounded retry always recovers.
    pub fn conn_drops(mut self, p: f64) -> ChaosPolicy {
        self.conn_drop_p = p;
        self
    }

    /// Corrupt each shard-RPC's reply frame with probability `p`, first
    /// attempt only; the flipped byte is caught by the frame CRC and the
    /// resend is guaranteed clean.
    pub fn reply_corruption(mut self, p: f64) -> ChaosPolicy {
        self.reply_corrupt_p = p;
        self
    }

    /// Cap on injected failures per victim task (default 2; keep it
    /// under the scheduler's `max_task_failures` or victims can never
    /// recover).
    pub fn max_injected_failures(mut self, k: u32) -> ChaosPolicy {
        self.max_injected_failures = k.max(1);
        self
    }

    /// The task-panic victim probability (used by the CLI to derive an
    /// emission-failure rate for `repro stream --serve --chaos`).
    pub fn task_panic_p(&self) -> f64 {
        self.task_panic_p
    }

    /// A per-victim random stream: pure function of the policy seed,
    /// a fault domain and the victim's identity.
    fn decide(&self, domain: u64, a: u64, b: u64, c: u64) -> Rng {
        let mut h = self.seed ^ domain.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        for x in [a, b, c] {
            h = (h ^ x).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 27;
        }
        Rng::new(h)
    }

    fn bump_attempt(&self, key: (u8, u64, u64)) -> u32 {
        let mut m = self.attempts.lock().unwrap_or_else(PoisonError::into_inner);
        let e = m.entry(key).or_insert(0);
        let seen = *e;
        *e += 1;
        seen
    }

    /// Decide the fault (if any) for one attempt of task
    /// `(job, stage, partition)`. Called by the stage scheduler before
    /// the task body runs, so injected faults never leave partial side
    /// effects behind.
    pub(crate) fn task_fault(
        &self,
        job: u64,
        stage: usize,
        partition: usize,
    ) -> Option<TaskFault> {
        let key = (0u8, job << 20 | stage as u64, partition as u64);
        let attempt = self.bump_attempt(key);
        let mut rng = self.decide(1, job, stage as u64, partition as u64);
        if self.task_panic_p > 0.0 && rng.chance(self.task_panic_p) {
            let k = 1 + (rng.next_u64() % u64::from(self.max_injected_failures)) as u32;
            if attempt < k {
                return Some(TaskFault::Panic);
            }
        }
        if self.straggler_p > 0.0 && rng.chance(self.straggler_p) && attempt == 0 {
            return Some(TaskFault::Straggle(self.straggler_delay));
        }
        None
    }

    /// Decide whether this fetch of `(shuffle, reduce)` fails. Only the
    /// first query of each pair can be a victim; the caller is expected
    /// to drop the shuffle's buckets and raise a fetch failure, so a
    /// `true` here is "one mid-job shuffle loss".
    pub(crate) fn fail_fetch(&self, shuffle: u64, reduce: usize) -> bool {
        let attempt = self.bump_attempt((1u8, shuffle, reduce as u64));
        if attempt > 0 || self.shuffle_loss_p <= 0.0 {
            return false;
        }
        self.decide(2, shuffle, reduce as u64, 0).chance(self.shuffle_loss_p)
    }

    /// Decide the fault (if any) for one attempt of shard RPC
    /// `(worker, rpc)` — `rpc` is the worker connection's logical RPC
    /// sequence number, so the identity is stable across retries. Only
    /// the first attempt of a given RPC can be a victim (one shared
    /// attempt counter covers both fault kinds), which bounds every
    /// injected net fault to a single retry — [`crate::net`] retries
    /// once, so a chaos run never loses a worker to injection alone.
    pub(crate) fn net_fault(&self, worker: u64, rpc: u64) -> Option<NetFault> {
        let attempt = self.bump_attempt((2u8, worker, rpc));
        if attempt > 0 {
            return None;
        }
        if self.conn_drop_p > 0.0 && self.decide(4, worker, rpc, 0).chance(self.conn_drop_p) {
            return Some(NetFault::DropConnection);
        }
        if self.reply_corrupt_p > 0.0 && self.decide(5, worker, rpc, 0).chance(self.reply_corrupt_p)
        {
            return Some(NetFault::CorruptReply);
        }
        None
    }

    /// Decide whether the next streaming emission fails. Consecutive
    /// injected failures are capped (see
    /// [`ChaosPolicy::emission_failures`]); a forced success resets the
    /// streak, mirroring how the service's own consecutive-failure
    /// counter resets on success.
    pub(crate) fn fail_emission(&self) -> bool {
        let mut st = self.emission_state.lock().unwrap_or_else(PoisonError::into_inner);
        let idx = st.0;
        st.0 += 1;
        if self.emission_p <= 0.0 {
            return false;
        }
        if st.1 >= self.max_emission_failures {
            st.1 = 0;
            return false;
        }
        if self.decide(3, idx, 0, 0).chance(self.emission_p) {
            st.1 += 1;
            true
        } else {
            st.1 = 0;
            false
        }
    }
}

impl Clone for ChaosPolicy {
    /// Clones share the seed and probabilities but reset the attempt
    /// counters: decisions are pure in the victim identity, so a clone
    /// re-injects the same fault set from scratch.
    fn clone(&self) -> ChaosPolicy {
        ChaosPolicy {
            seed: self.seed,
            task_panic_p: self.task_panic_p,
            max_injected_failures: self.max_injected_failures,
            straggler_p: self.straggler_p,
            straggler_delay: self.straggler_delay,
            shuffle_loss_p: self.shuffle_loss_p,
            emission_p: self.emission_p,
            max_emission_failures: self.max_emission_failures,
            conn_drop_p: self.conn_drop_p,
            reply_corrupt_p: self.reply_corrupt_p,
            attempts: Mutex::new(HashMap::new()),
            emission_state: Mutex::new((0, 0)),
        }
    }
}

impl fmt::Debug for ChaosPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ChaosPolicy")
            .field("seed", &self.seed)
            .field("task_panic_p", &self.task_panic_p)
            .field("straggler_p", &self.straggler_p)
            .field("shuffle_loss_p", &self.shuffle_loss_p)
            .field("emission_p", &self.emission_p)
            .finish_non_exhaustive()
    }
}

impl fmt::Display for ChaosPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "seed={} task-panic p={:.2} straggler p={:.2} ({:?}) shuffle-loss p={:.2} \
             emission p={:.2} conn-drop p={:.2} reply-corrupt p={:.2}",
            self.seed,
            self.task_panic_p,
            self.straggler_p,
            self.straggler_delay,
            self.shuffle_loss_p,
            self.emission_p,
            self.conn_drop_p,
            self.reply_corrupt_p
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_seed_colon_p() {
        let c = ChaosPolicy::parse("7:0.2").unwrap();
        assert_eq!(c.seed, 7);
        assert!((c.task_panic_p - 0.2).abs() < 1e-12);
        assert!((c.straggler_p - 0.1).abs() < 1e-12);
        assert!((c.shuffle_loss_p - 0.1).abs() < 1e-12);
        assert!((c.conn_drop_p - 0.1).abs() < 1e-12);
        assert!((c.reply_corrupt_p - 0.1).abs() < 1e-12);
        assert!(c.emission_p == 0.0);
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in ["", "7", "x:0.2", "7:x", "7:1.5", "7:-0.1"] {
            assert!(ChaosPolicy::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn task_faults_are_deterministic_and_bounded() {
        let a = ChaosPolicy::new(42).task_panics(1.0);
        let b = a.clone();
        for (job, stage, p) in [(0u64, 0usize, 0usize), (0, 1, 3), (9, 0, 7)] {
            // Same victim, same decisions on both clones; panics stop
            // after at most `max_injected_failures` attempts.
            let mut panics = 0;
            for attempt in 0..6 {
                let fa = a.task_fault(job, stage, p);
                let fb = b.task_fault(job, stage, p);
                assert_eq!(fa, fb, "attempt {attempt} diverged");
                if fa == Some(TaskFault::Panic) {
                    panics += 1;
                }
            }
            assert!(panics >= 1 && panics <= 2, "panics = {panics}");
            assert_ne!(a.task_fault(job, stage, p), Some(TaskFault::Panic));
        }
    }

    #[test]
    fn stragglers_only_hit_the_first_attempt() {
        let c = ChaosPolicy::new(3).stragglers(1.0, Duration::from_millis(5));
        assert_eq!(
            c.task_fault(1, 0, 0),
            Some(TaskFault::Straggle(Duration::from_millis(5)))
        );
        assert_eq!(c.task_fault(1, 0, 0), None);
    }

    #[test]
    fn fetch_failure_fires_at_most_once_per_reduce() {
        let c = ChaosPolicy::new(5).shuffle_loss(1.0);
        assert!(c.fail_fetch(2, 0));
        assert!(!c.fail_fetch(2, 0), "second fetch of the pair must succeed");
        assert!(c.fail_fetch(2, 1), "other reduce partitions decide independently");
    }

    #[test]
    fn emission_failures_respect_the_consecutive_cap() {
        let c = ChaosPolicy::new(1).emission_failures(1.0, 2);
        let run: Vec<bool> = (0..9).map(|_| c.fail_emission()).collect();
        assert_eq!(run, vec![true, true, false, true, true, false, true, true, false]);
    }

    #[test]
    fn net_faults_are_deterministic_and_first_attempt_only() {
        let a = ChaosPolicy::new(11).conn_drops(1.0);
        let b = a.clone();
        for rpc in 0..8u64 {
            let fa = a.net_fault(0, rpc);
            assert_eq!(fa, b.net_fault(0, rpc), "rpc {rpc} diverged across clones");
            assert_eq!(fa, Some(NetFault::DropConnection));
            assert_eq!(a.net_fault(0, rpc), None, "retry of rpc {rpc} must be clean");
        }
        // Corruption decides independently per (worker, rpc) and is
        // likewise bounded to the first attempt.
        let c = ChaosPolicy::new(11).reply_corruption(1.0);
        assert_eq!(c.net_fault(3, 0), Some(NetFault::CorruptReply));
        assert_eq!(c.net_fault(3, 0), None);
        // A drop decision shadows corruption on the same attempt: one
        // fault per RPC, never both.
        let d = ChaosPolicy::new(11).conn_drops(1.0).reply_corruption(1.0);
        assert_eq!(d.net_fault(0, 0), Some(NetFault::DropConnection));
        assert_eq!(d.net_fault(0, 0), None);
    }

    #[test]
    fn unarmed_policy_injects_nothing() {
        let c = ChaosPolicy::new(7);
        for p in 0..64 {
            assert_eq!(c.task_fault(0, 0, p), None);
            assert!(!c.fail_fetch(0, p));
        }
        assert!(!c.fail_emission());
        assert_eq!(c.net_fault(0, 0), None);
    }

    #[test]
    fn display_mentions_seed_and_probabilities() {
        let c = ChaosPolicy::default_suite(7, 0.2);
        let s = c.to_string();
        assert!(s.contains("seed=7"), "{s}");
        assert!(s.contains("task-panic p=0.20"), "{s}");
    }
}
