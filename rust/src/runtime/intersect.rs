//! XLA-backed batched tidset intersection (the `popcount` artifact).
//!
//! Eclat's bottom-up inner loop performs many independent
//! `|t(A) ∩ t(B)|` counts; this backend batches them into `(N, W)` u32
//! lane matrices and runs the AOT popcount kernel via PJRT. The A4
//! ablation compares it against the native u64 popcount sweep
//! ([`crate::fim::TidBitmap::and_count`]).

use std::sync::Arc;

use crate::error::Result;
use crate::fim::TidBitmap;

use super::service::{HostBuffer, XlaService};

/// Pairs per PJRT call — matches the AOT artifact.
pub const TILE_N: usize = 256;
/// u32 lanes per bitmap row — matches the AOT artifact (2048 tids).
pub const TILE_W: usize = 64;

/// The PJRT-backed batch intersection engine.
pub struct XlaIntersect {
    svc: Arc<XlaService>,
    artifact: String,
}

impl XlaIntersect {
    /// Wrap a running service (expects `popcount_256x64`).
    pub fn new(svc: Arc<XlaService>) -> XlaIntersect {
        XlaIntersect { svc, artifact: format!("popcount_{TILE_N}x{TILE_W}") }
    }

    /// Compute `|a ∩ b|` for every pair. Universes larger than one tile
    /// (2048 tids) accumulate over word windows; batches larger than
    /// `TILE_N` run in multiple calls.
    pub fn batch_supports(&self, pairs: &[(&TidBitmap, &TidBitmap)]) -> Result<Vec<u32>> {
        let mut out = vec![0u32; pairs.len()];
        if pairs.is_empty() {
            return Ok(out);
        }
        let max_lanes = pairs
            .iter()
            .map(|(a, b)| a.words().len().max(b.words().len()) * 2)
            .max()
            .unwrap_or(0);
        let windows = max_lanes.div_ceil(TILE_W);
        let dims = vec![TILE_N as i64, TILE_W as i64];

        for (batch_idx, batch) in pairs.chunks(TILE_N).enumerate() {
            for win in 0..windows {
                let lane_off = win * TILE_W;
                let mut a_buf = vec![0u32; TILE_N * TILE_W];
                let mut b_buf = vec![0u32; TILE_N * TILE_W];
                let mut any = false;
                for (r, (a, b)) in batch.iter().enumerate() {
                    any |= fill_lanes(&mut a_buf[r * TILE_W..(r + 1) * TILE_W], a, lane_off);
                    any |= fill_lanes(&mut b_buf[r * TILE_W..(r + 1) * TILE_W], b, lane_off);
                }
                if !any {
                    continue;
                }
                let res = self.svc.execute(
                    &self.artifact,
                    vec![HostBuffer::U32(a_buf, dims.clone()), HostBuffer::U32(b_buf, dims.clone())],
                )?;
                let counts = res[0].as_i32()?;
                for (r, &c) in counts.iter().take(batch.len()).enumerate() {
                    out[batch_idx * TILE_N + r] += c as u32;
                }
            }
        }
        Ok(out)
    }
}

/// Copy one window of u32 lanes out of a bitmap's u64 words. Returns
/// whether anything nonzero was written.
fn fill_lanes(dst: &mut [u32], bm: &TidBitmap, lane_off: usize) -> bool {
    let words = bm.words();
    let mut any = false;
    for (i, d) in dst.iter_mut().enumerate() {
        let lane = lane_off + i;
        let w = lane / 2;
        if w >= words.len() {
            break;
        }
        let v = if lane % 2 == 0 { words[w] as u32 } else { (words[w] >> 32) as u32 };
        *d = v;
        any |= v != 0;
    }
    any
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    fn random_bitmap(rng: &mut Rng, universe: usize, density: f64) -> TidBitmap {
        let mut bm = TidBitmap::new(universe);
        for t in 0..universe {
            if rng.chance(density) {
                bm.insert(t as u32);
            }
        }
        bm
    }

    #[test]
    fn matches_native_and_count_small_universe() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = Arc::new(XlaService::start(dir).unwrap());
        let xi = XlaIntersect::new(svc);
        let mut rng = Rng::new(1);
        let bitmaps: Vec<(TidBitmap, TidBitmap)> = (0..40)
            .map(|_| (random_bitmap(&mut rng, 500, 0.3), random_bitmap(&mut rng, 500, 0.3)))
            .collect();
        let pairs: Vec<(&TidBitmap, &TidBitmap)> =
            bitmaps.iter().map(|(a, b)| (a, b)).collect();
        let got = xi.batch_supports(&pairs).unwrap();
        for (i, (a, b)) in bitmaps.iter().enumerate() {
            assert_eq!(got[i], a.and_count(b), "pair {i}");
        }
    }

    #[test]
    fn matches_native_large_universe_and_large_batch() {
        let Some(dir) = artifacts_dir() else { return };
        // Universe 5000 tids -> 3 windows; 300 pairs -> 2 batches.
        let svc = Arc::new(XlaService::start(dir).unwrap());
        let xi = XlaIntersect::new(svc);
        let mut rng = Rng::new(2);
        let bitmaps: Vec<(TidBitmap, TidBitmap)> = (0..300)
            .map(|_| (random_bitmap(&mut rng, 5000, 0.1), random_bitmap(&mut rng, 5000, 0.1)))
            .collect();
        let pairs: Vec<(&TidBitmap, &TidBitmap)> =
            bitmaps.iter().map(|(a, b)| (a, b)).collect();
        let got = xi.batch_supports(&pairs).unwrap();
        for (i, (a, b)) in bitmaps.iter().enumerate() {
            assert_eq!(got[i], a.and_count(b), "pair {i}");
        }
    }

    #[test]
    fn empty_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = Arc::new(XlaService::start(dir).unwrap());
        let xi = XlaIntersect::new(svc);
        assert!(xi.batch_supports(&[]).unwrap().is_empty());
    }
}
